//! Lexical file scanner for `deigen-lint` (DESIGN.md S18).
//!
//! Rules never see raw source: they see *masked* lines where comment
//! bodies and string/char-literal contents have been blanked to spaces
//! (delimiters are kept so token boundaries survive). That is what makes
//! the pass self-clean — the rule patterns in `rules.rs` live inside
//! string literals, and a snippet like `".partial_cmp("` in a doc comment
//! cannot fire a finding. On top of the mask the scanner derives the
//! structure the rules need: per-line test-code flags (`#[cfg(test)]`
//! blocks and `#[test]` functions), `fn` body spans for scope-granular
//! rules (send-implies-meter), and the `// deigen-lint: allow(<rule>) —
//! <reason>` suppression annotations, which are themselves audited by the
//! engine (an allow that suppresses nothing is an error).
//!
//! The scanner is a line/token pass, not a parser: it tracks exactly the
//! Rust surface it needs (nested block comments, raw strings `r#"…"#`,
//! byte strings, char-vs-lifetime disambiguation, brace depth) and
//! nothing more. Findings are line-granular, which is the granularity the
//! suppression syntax works at.

/// One suppression annotation: `// deigen-lint: allow(<rule>) — <reason>`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Allow {
    /// Rule id inside the parens.
    pub rule: String,
    /// 1-indexed line the annotation sits on. It suppresses findings of
    /// `rule` on this line and the immediately following line.
    pub line: usize,
    /// Free-text justification after the rule. Mandatory: an allow
    /// without a reason is reported by the audit.
    pub reason: String,
}

/// A `fn` body span (1-indexed, inclusive of the line holding the
/// closing brace). Nested items stay inside their parent's span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FnSpan {
    pub start: usize,
    pub end: usize,
}

impl FnSpan {
    pub fn contains(&self, line: usize) -> bool {
        self.start <= line && line <= self.end
    }
}

/// Everything the rule engine needs to know about one source file.
pub struct FileScan {
    /// Masked source, split into lines (no trailing newlines).
    pub masked: Vec<String>,
    /// Per-line: is this line inside `#[cfg(test)]`-gated code or a
    /// `#[test]` function body?
    pub is_test: Vec<bool>,
    /// All suppression annotations, in line order.
    pub allows: Vec<Allow>,
    /// Annotations that *look* like deigen-lint directives but do not
    /// parse (missing rule, missing reason). `(line, problem)`.
    pub malformed: Vec<(usize, String)>,
    /// All `fn` body spans, innermost-last per nesting chain.
    pub fns: Vec<FnSpan>,
}

impl FileScan {
    /// Masked text of 1-indexed `line` ("" out of range).
    pub fn line(&self, line: usize) -> &str {
        self.masked.get(line.wrapping_sub(1)).map(String::as_str).unwrap_or("")
    }

    /// Innermost `fn` span containing `line`, if any.
    pub fn enclosing_fn(&self, line: usize) -> Option<FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.contains(line))
            .min_by_key(|f| f.end - f.start)
            .copied()
    }
}

/// Scan one file.
pub fn scan(text: &str) -> FileScan {
    let (masked_text, comments) = mask(text);
    let masked: Vec<String> = masked_text.split('\n').map(str::to_string).collect();
    let (is_test, fns) = analyze(&masked);
    let mut allows = Vec::new();
    let mut malformed = Vec::new();
    for (line, body) in &comments {
        match parse_allow(body) {
            Some(Ok((rule, reason))) => allows.push(Allow { rule, line: *line, reason }),
            Some(Err(problem)) => malformed.push((*line, problem)),
            None => {}
        }
    }
    FileScan { masked, is_test, allows, malformed, fns }
}

/// Does `line` contain `word` as a standalone token (non-identifier
/// characters, or the line boundary, on both sides)?
pub fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let after = at + word.len();
        let after_ok = after >= bytes.len() || !is_ident(bytes[after]);
        if before_ok && after_ok {
            return true;
        }
        from = at + word.len();
    }
    false
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

// ---------------------------------------------------------------------
// masking state machine
// ---------------------------------------------------------------------

/// Blank comment bodies and string/char contents to spaces, preserving
/// newlines, delimiters and everything else. Returns the masked text and
/// the collected comment bodies as `(1-indexed line, text)` — block
/// comments contribute one entry per line they cover.
fn mask(text: &str) -> (String, Vec<(usize, String)>) {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(text.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut cur = String::new();
    let mut in_comment = false;
    let mut line = 1usize;
    let mut i = 0usize;

    enum St {
        Code,
        Line,
        Block(u32),
        Str,
        RawStr(u32),
    }
    let mut st = St::Code;

    macro_rules! flush_comment {
        () => {
            if in_comment {
                comments.push((line, std::mem::take(&mut cur)));
                in_comment = false;
            }
        };
    }

    while i < n {
        let c = chars[i];
        match st {
            St::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = St::Line;
                    in_comment = true;
                    out.push_str("  ");
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::Block(1);
                    in_comment = true;
                    out.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    out.push('"');
                    i += 1;
                } else if c == 'r' && raw_str_hashes(&chars, i).is_some() {
                    let h = raw_str_hashes(&chars, i).unwrap();
                    out.push('r');
                    for _ in 0..h {
                        out.push('#');
                    }
                    out.push('"');
                    st = St::RawStr(h);
                    i += 2 + h as usize;
                } else if c == '\'' {
                    // char literal vs lifetime
                    if chars.get(i + 1) == Some(&'\\') {
                        // escaped char literal: consume to the closing quote
                        out.push('\'');
                        i += 1;
                        while i < n && chars[i] != '\'' {
                            if chars[i] == '\\' {
                                out.push_str("  ");
                                i += 2;
                            } else {
                                if chars[i] == '\n' {
                                    out.push('\n');
                                    line += 1;
                                } else {
                                    out.push(' ');
                                }
                                i += 1;
                            }
                        }
                        if i < n {
                            out.push('\'');
                            i += 1;
                        }
                    } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                        // plain char literal 'x' (any single char)
                        out.push('\'');
                        out.push(' ');
                        out.push('\'');
                        i += 3;
                    } else {
                        // lifetime — emit the quote, stay in code
                        out.push('\'');
                        i += 1;
                    }
                } else {
                    if c == '\n' {
                        line += 1;
                    }
                    out.push(c);
                    i += 1;
                }
            }
            St::Line => {
                if c == '\n' {
                    flush_comment!();
                    st = St::Code;
                    out.push('\n');
                    line += 1;
                } else {
                    cur.push(c);
                    out.push(' ');
                }
                i += 1;
            }
            St::Block(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::Block(depth + 1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    if depth == 1 {
                        flush_comment!();
                        st = St::Code;
                    } else {
                        st = St::Block(depth - 1);
                    }
                    out.push_str("  ");
                    i += 2;
                } else {
                    if c == '\n' {
                        flush_comment!();
                        in_comment = true; // continues on the next line
                        out.push('\n');
                        line += 1;
                    } else {
                        cur.push(c);
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    out.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    out.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    if c == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && closes_raw(&chars, i, h) {
                    out.push('"');
                    for _ in 0..h {
                        out.push('#');
                    }
                    st = St::Code;
                    i += 1 + h as usize;
                } else {
                    if c == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
        }
    }
    if in_comment {
        comments.push((line, cur));
    }
    (out, comments)
}

/// Is `chars[i] == 'r'` the start of a raw string? Returns the hash
/// count. Requires a non-identifier character before the `r` so
/// identifiers ending in `r` (e.g. `var"x"` can't occur, but `r` inside
/// a path could) never false-trigger.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<u32> {
    if i > 0 {
        let p = chars[i - 1];
        if p.is_alphanumeric() || p == '_' {
            return None;
        }
    }
    let mut j = i + 1;
    let mut h = 0u32;
    while chars.get(j) == Some(&'#') {
        h += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(h)
    } else {
        None
    }
}

/// Does the quote at `i` close a raw string with `h` hashes?
fn closes_raw(chars: &[char], i: usize, h: u32) -> bool {
    (1..=h as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

// ---------------------------------------------------------------------
// structural analysis over masked lines
// ---------------------------------------------------------------------

/// Per-line test flags and `fn` spans, from brace tracking over the
/// masked lines. `#[cfg(test)]` arms a flag that marks the next
/// brace-delimited item (the `mod tests { … }` block, or a gated helper
/// `fn`) as test code; a `;` before any `{` (e.g. `#[cfg(test)] use …;`)
/// disarms it.
fn analyze(masked: &[String]) -> (Vec<bool>, Vec<FnSpan>) {
    let mut is_test = vec![false; masked.len()];
    let mut fns: Vec<FnSpan> = Vec::new();
    let mut depth: i64 = 0;
    let mut pending_cfg = false;
    let mut pending_fn: Option<usize> = None;
    let mut test_entry: Vec<i64> = Vec::new();
    let mut open_fns: Vec<(usize, i64)> = Vec::new();

    for (idx, line) in masked.iter().enumerate() {
        let lineno = idx + 1;
        if !test_entry.is_empty() {
            is_test[idx] = true;
        }
        if line.contains("#[cfg(test)]") || line.contains("#[test]") {
            pending_cfg = true;
        }
        if has_word(line, "fn") {
            // position is resolved by the token walk below; recording the
            // line here is enough because the walk only needs the start
            pending_fn = Some(lineno);
        }
        for ch in line.chars() {
            match ch {
                '{' => {
                    if pending_cfg {
                        test_entry.push(depth);
                        pending_cfg = false;
                        is_test[idx] = true;
                    }
                    if let Some(s) = pending_fn.take() {
                        open_fns.push((s, depth));
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if open_fns.last().is_some_and(|&(_, d)| d == depth) {
                        let (s, _) = open_fns.pop().unwrap();
                        fns.push(FnSpan { start: s, end: lineno });
                    }
                    if test_entry.last() == Some(&depth) {
                        test_entry.pop();
                        is_test[idx] = true;
                    }
                }
                ';' => {
                    // `fn f(…) -> T;` (trait decl) and `#[cfg(test)] use …;`:
                    // a semicolon before any `{` closes the pending item
                    pending_fn = None;
                    pending_cfg = false;
                }
                _ => {}
            }
        }
    }
    fns.sort_by_key(|f| (f.start, f.end));
    (is_test, fns)
}

// ---------------------------------------------------------------------
// suppression annotations
// ---------------------------------------------------------------------

/// Parse one comment body. `None` — not a deigen-lint directive at all.
/// `Some(Ok((rule, reason)))` — well-formed allow. `Some(Err(why))` —
/// directive-shaped but malformed (audited as an error by the engine).
///
/// A directive must *begin* the comment body (after whitespace), i.e. be
/// written `// deigen-lint: …` or trail code as `x(); // deigen-lint: …`.
/// Doc comments (`///` and `//!` leave a leading `/` or `!` in the body)
/// and prose that merely mentions the marker mid-sentence never parse as
/// directives — documentation about the syntax cannot trip the audit.
fn parse_allow(body: &str) -> Option<Result<(String, String), String>> {
    let rest = body.trim_start().strip_prefix("deigen-lint:")?.trim_start();
    let Some(inner) = rest.strip_prefix("allow(") else {
        return Some(Err(format!("expected `allow(<rule>)` after `deigen-lint:`, got `{rest}`")));
    };
    let Some(close) = inner.find(')') else {
        return Some(Err("unterminated `allow(` — missing `)`".to_string()));
    };
    let rule = inner[..close].trim().to_string();
    if rule.is_empty() {
        return Some(Err("empty rule id in `allow()`".to_string()));
    }
    let mut reason = inner[close + 1..].trim_start();
    for sep in ["—", "--", "-", ":"] {
        if let Some(r) = reason.strip_prefix(sep) {
            reason = r;
            break;
        }
    }
    let reason = reason.trim();
    if reason.is_empty() {
        return Some(Err(format!("allow({rule}) has no justification — a reason is mandatory")));
    }
    Some(Ok((rule, reason.to_string())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_line_and_block_comments() {
        let s = scan("let x = 1; // partial_cmp().unwrap()\n/* unsafe */ let y = 2;\n");
        assert!(!s.line(1).contains("partial_cmp"));
        assert!(s.line(1).contains("let x = 1;"));
        assert!(!s.line(2).contains("unsafe"));
        assert!(s.line(2).contains("let y = 2;"));
    }

    #[test]
    fn masks_string_contents_but_keeps_delimiters() {
        let s = scan("let p = \".unwrap()\";\nlet q = r#\"HashMap\"#;\n");
        assert!(!s.line(1).contains("unwrap"));
        assert!(s.line(1).contains('"'));
        assert!(!s.line(2).contains("HashMap"));
    }

    #[test]
    fn nested_block_comments_and_multiline_strings() {
        let s = scan("/* a /* b */ still comment */ code();\nlet s = \"one\\\n two\";\nafter();\n");
        assert!(s.line(1).contains("code();"));
        assert!(!s.line(2).contains("one"));
        assert!(s.line(3).contains("after();"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let s = scan("fn f<'a>(x: &'a str) -> &'a str { let c = 'x'; let t = '\\n'; x }\n");
        // the fn span must close on line 1 — a runaway char literal would
        // swallow the braces
        assert_eq!(s.fns, vec![FnSpan { start: 1, end: 1 }]);
        assert!(!s.line(1).contains('x') || s.line(1).contains("x }"));
    }

    #[test]
    fn cfg_test_block_is_flagged() {
        let text = "fn real() {}\n#[cfg(test)]\nmod tests {\n    fn helper() { bad(); }\n}\nfn tail() {}\n";
        let s = scan(text);
        assert!(!s.is_test[0]);
        assert!(s.is_test[3], "inside mod tests");
        assert!(s.is_test[4], "closing brace line");
        assert!(!s.is_test[5], "after the block");
    }

    #[test]
    fn cfg_test_on_use_does_not_leak() {
        let s = scan("#[cfg(test)]\nuse super::*;\nfn live() { x(); }\n");
        assert!(!s.is_test[2], "cfg(test) on a use must not mark the next fn");
    }

    #[test]
    fn fn_spans_nest() {
        let text = "fn outer() {\n    fn inner() {\n        y();\n    }\n    x();\n}\n";
        let s = scan(text);
        assert_eq!(s.fns, vec![
            FnSpan { start: 2, end: 4 },
            FnSpan { start: 1, end: 6 },
        ]);
        assert_eq!(s.enclosing_fn(3), Some(FnSpan { start: 2, end: 4 }));
        assert_eq!(s.enclosing_fn(5), Some(FnSpan { start: 1, end: 6 }));
    }

    #[test]
    fn trait_method_decl_does_not_open_a_span() {
        let s = scan("trait T {\n    fn decl(&self) -> usize;\n    fn body(&self) { g(); }\n}\n");
        assert_eq!(s.fns, vec![FnSpan { start: 3, end: 3 }]);
    }

    #[test]
    fn allow_parsing_and_malformed() {
        let text = "\
// deigen-lint: allow(no-unsafe-outside-pool) — FFI Send wrapper, no shared state\n\
let x = 1; // deigen-lint: allow(float-bits-in-snapshots): integer cast is exact\n\
// deigen-lint: allow(no-stray-threads)\n\
// ordinary comment mentioning deigen-lint usage in prose is fine\n";
        let s = scan(text);
        assert_eq!(s.allows.len(), 2);
        assert_eq!(s.allows[0].rule, "no-unsafe-outside-pool");
        assert_eq!(s.allows[0].line, 1);
        assert!(s.allows[0].reason.contains("FFI"));
        assert_eq!(s.allows[1].rule, "float-bits-in-snapshots");
        assert_eq!(s.allows[1].line, 2);
        // line 3 lacks a reason → malformed; line 4 is plain prose where
        // the marker does not begin the comment body → ignored
        assert_eq!(s.malformed.len(), 1);
        assert_eq!(s.malformed[0].0, 3);
    }

    #[test]
    fn doc_comments_about_the_syntax_are_not_directives() {
        let text = "\
/// Suppressions look like `// deigen-lint: allow(<rule>) — <reason>`.\n\
//! The `// deigen-lint: allow(x)` form is audited.\n\
// see deigen-lint: allow(...) in DESIGN.md S18 for the grammar\n\
fn documented() {}\n";
        let s = scan(text);
        assert!(s.allows.is_empty());
        assert!(s.malformed.is_empty(), "doc/prose mentions must not parse: {:?}", s.malformed);
    }

    #[test]
    fn has_word_respects_boundaries() {
        assert!(has_word("pub fn f()", "fn"));
        assert!(!has_word("Mat::from_fn(a, b)", "fn"));
        assert!(!has_word("fnord", "fn"));
        assert!(has_word("unsafe {", "unsafe"));
    }
}
