//! Vendored, offline-buildable subset of the `anyhow` API.
//!
//! The container this repo builds in has no network access, so crates.io
//! dependencies cannot be fetched; this path dependency provides the
//! pieces of `anyhow` the codebase actually uses with identical call-site
//! syntax:
//!
//! - [`Error`]: an opaque error carrying a context chain;
//! - [`Result<T>`]: alias with `Error` as the default error type;
//! - [`anyhow!`], [`bail!`], [`ensure!`]: message/format-string macros;
//! - [`Context`]: `.context(..)` / `.with_context(|| ..)` on `Result`
//!   (for any `std::error::Error`) and on `Option`;
//! - blanket `From<E: std::error::Error>` so `?` converts foreign errors.
//!
//! Like real `anyhow`, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent. `{err}` prints the outermost message; `{err:#}` prints the
//! whole context chain separated by `": "`; `{err:?}` prints the chain in
//! the "Caused by" style.

use std::fmt;

/// Opaque error: an outermost message plus the chain of underlying causes
/// (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion `?` relies on. Coherent only because `Error`
// itself does not implement `std::error::Error` (anyhow's trick).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_build_errors() {
        let n = 3;
        let e = anyhow!("bad value {n}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e = anyhow!(String::from("plain"));
        assert_eq!(format!("{e}"), "plain");

        fn guarded(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(guarded(5).unwrap(), 5);
        assert_eq!(format!("{}", guarded(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", guarded(200).unwrap_err()), "too big");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
    }
}
