//! Cross-engine integration tests: the PJRT executables (AOT-compiled from
//! the JAX/Pallas stack) must agree with the from-scratch native engine on
//! every artifact shape. This is the key correctness seam of the
//! three-layer design: L1/L2 numerics (f32, Newton–Schulz, Pallas tiling)
//! vs the independent rust implementation (f64, Householder/Jacobi).
//!
//! Requires `make artifacts` AND a build with the `pjrt` feature; the
//! cross-engine tests skip gracefully when either is missing (CI without
//! Python, offline builds with the stub engine). The suite still earns
//! its keep in those environments: the second half pins the **native**
//! engine to the testkit oracles at the exact artifact shapes, so the
//! gold standard the PJRT side is compared against is itself verified.

use deigen::linalg::gemm::syrk_scaled;
use deigen::linalg::procrustes::procrustes_align;
use deigen::linalg::subspace::{dist2, is_orthonormal};
use deigen::rng::Pcg64;
use deigen::runtime::{LocalSolver, Manifest, NativeEngine, PjrtEngine};
use deigen::synth::{CovModel, SpectrumModel};
use deigen::testkit::{check, gen, oracle, tol};

/// The (d, r) shapes `aot.py` bakes `local_eig_cov` artifacts for.
const ARTIFACT_SHAPES: &[(usize, usize)] = &[(64, 8), (128, 16)];

fn engine_or_skip() -> Option<PjrtEngine> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match PjrtEngine::load_default() {
        Ok(engine) => Some(engine),
        Err(e) if !cfg!(feature = "pjrt") => {
            // stub build: cross-engine comparison is impossible by
            // construction; the native-vs-oracle tests below still run
            eprintln!("skipping: PJRT engine unavailable ({e:#})");
            None
        }
        // real-engine build with artifacts present: a load failure is a
        // regression, not a skip — fail loudly
        Err(e) => panic!("PJRT engine failed to load with `pjrt` enabled: {e:#}"),
    }
}

// ---------------------------------------------------------------------
// PJRT vs native (skip without artifacts + the `pjrt` feature)
// ---------------------------------------------------------------------

#[test]
fn gram_artifact_matches_native_syrk() {
    let Some(mut engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::seed(1);
    let x = rng.normal_mat(500, 64);
    let pjrt = engine.gram(&x).unwrap();
    let native = syrk_scaled(&x, 500.0);
    let err = pjrt.sub(&native).max_abs();
    assert!(err < 1e-3, "gram mismatch {err}"); // f32 artifact vs f64 native
}

#[test]
fn procrustes_artifact_matches_native() {
    let Some(mut engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::seed(2);
    for _ in 0..3 {
        let vref = rng.haar_stiefel(64, 8);
        let z = rng.haar_orthogonal(8);
        let noisy = deigen::linalg::gemm::matmul(&vref, &z)
            .add(&rng.normal_mat(64, 8).scale(0.05));
        let v = deigen::linalg::qr::orthonormalize(&noisy);
        let pjrt = engine.procrustes(&v, &vref).unwrap();
        let native = procrustes_align(&v, &vref);
        let err = pjrt.sub(&native).max_abs();
        assert!(err < 5e-3, "procrustes mismatch {err}");
    }
}

#[test]
fn local_eig_artifact_finds_same_subspace() {
    let Some(mut engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::seed(3);
    let model = SpectrumModel::M1 { r: 8, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, 64, &mut rng);
    let x = cov.sample(500, &mut rng);
    let v0 = rng.normal_mat(64, 8);

    let (v_pjrt, ritz) = engine.local_eig(&x, &v0).unwrap();
    assert!(is_orthonormal(&v_pjrt, 1e-3));
    assert_eq!(ritz.len(), 8);

    // native gold standard: dense eigensolver on the same empirical cov
    let c = CovModel::empirical_cov(&x);
    let v_dense = deigen::linalg::eig::top_eigvecs(&c, 8).0;
    let d = dist2(&v_pjrt, &v_dense);
    assert!(d < 5e-2, "subspace mismatch {d}");

    // Ritz values within the empirical spectrum range
    let (vals, _) = deigen::linalg::eig::sym_eig(&c);
    let (lo, hi) = (vals[64 - 8] - 0.05, vals[63] + 0.05);
    for &t in &ritz {
        assert!(t > lo && t < hi, "ritz {t} outside [{lo}, {hi}]");
    }
}

#[test]
fn local_eig_cov_artifact_all_shapes() {
    let Some(mut engine) = engine_or_skip() else { return };
    let manifest = Manifest::load(Manifest::default_dir()).unwrap();
    let mut rng = Pcg64::seed(4);
    for (d, r) in manifest.local_eig_cov_shapes() {
        let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
        let cov = CovModel::draw(&model, d, &mut rng);
        let sigma = cov.sigma();
        let v0 = rng.normal_mat(d, r);
        let (v, _) = engine.local_eig_cov(&sigma, &v0).unwrap();
        let truth = cov.principal_subspace();
        let dist = dist2(&v, &truth);
        assert!(dist < 1e-2, "({d},{r}): dist {dist}");
    }
}

#[test]
fn pjrt_rejects_unknown_shapes() {
    let Some(mut engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::seed(5);
    let x = rng.normal_mat(7, 7);
    assert!(engine.gram(&x).is_err());
    assert!(!engine.supports_cov_shape(7, 3));
}

#[test]
fn pjrt_deterministic_across_calls() {
    let Some(mut engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::seed(6);
    let x = rng.normal_mat(500, 64);
    let a = engine.gram(&x).unwrap();
    let b = engine.gram(&x).unwrap();
    assert!(a.sub(&b).max_abs() == 0.0);
}

// ---------------------------------------------------------------------
// native engine vs testkit oracles at the artifact shapes (always run)
// ---------------------------------------------------------------------

/// Without the `pjrt` feature the stub engine must refuse to load with a
/// descriptive error instead of panicking or pretending to work.
#[test]
fn stub_engine_fails_loudly_not_silently() {
    if cfg!(feature = "pjrt") {
        return; // real engine: behavior covered by the tests above
    }
    match PjrtEngine::load_default() {
        Ok(_) => panic!("stub PjrtEngine must not construct"),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("pjrt"),
                "stub error should name the missing feature: {msg}"
            );
        }
    }
}

/// The native gram (SYRK) path at the gram artifact shape (500, 64),
/// pinned to the oracle Gram.
#[test]
fn native_gram_matches_oracle_at_artifact_shape() {
    let mut rng = Pcg64::seed(7);
    let x = rng.normal_mat(500, 64);
    check::assert_close(
        &syrk_scaled(&x, 500.0),
        &oracle::gram_scaled(&x, 500.0),
        tol::dim_scaled(tol::KERNEL, 500),
        "native gram at artifact shape (500, 64)",
    );
}

/// The native local eigensolver at every artifact (d, r): must find the
/// planted subspace of a spiked covariance, judged by the oracle sin-Θ.
#[test]
fn native_engine_matches_oracle_at_artifact_shapes() {
    for &(d, r) in ARTIFACT_SHAPES {
        let cov = gen::spiked_covariance(d, r, 1.0, 0.5, 8000 + d as u64);
        let sigma = cov.sigma();
        let mut rng = Pcg64::seed(9000 + d as u64);
        let v = NativeEngine::default().leading_subspace(&sigma, r, &mut rng);
        check::assert_orthonormal(&v, tol::FACTOR, &format!("native panel ({d},{r})"));
        let dist = check::sin_theta(&v, &cov.truth());
        assert!(
            dist < 100.0 * tol::ITER,
            "({d},{r}): native engine missed the planted subspace ({dist:.2e})"
        );
    }
}

/// The native Procrustes solve at the procrustes artifact shape (64, 8):
/// oracle agreement plus the optimality certificate.
#[test]
fn native_procrustes_certified_at_artifact_shape() {
    let (d, r) = (64usize, 8usize);
    let truth = gen::haar_panel(d, r, 42);
    let pair = gen::noisy_copies(&truth, 2, 0.05, 43);
    let (v, vref) = (&pair[0], &pair[1]);
    let z = deigen::linalg::procrustes::procrustes_rotation(v, vref);
    assert!(
        check::procrustes_certificate(v, vref, &z) < tol::ITER,
        "certificate violated at artifact shape"
    );
    check::assert_close(
        &z,
        &oracle::procrustes_rotation(v, vref),
        tol::ITER,
        "native rotation vs oracle at artifact shape",
    );
}
