//! Known-good twin: `BTreeMap` gives the same API with a deterministic
//! (sorted) iteration order — the sanctioned container in coordinator
//! code.

use std::collections::BTreeMap;

pub fn tally(votes: &[(u32, bool)]) -> usize {
    let mut by_peer: BTreeMap<u32, bool> = BTreeMap::new();
    for &(peer, up) in votes {
        by_peer.insert(peer, up);
    }
    by_peer.values().filter(|&&v| v).count()
}
