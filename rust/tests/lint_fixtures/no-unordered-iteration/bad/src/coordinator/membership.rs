//! Known-bad: hash containers in the coordinator. Iteration order is
//! seed-dependent, so any protocol decision derived from it (peer order,
//! quorum tallies, transcript layout) silently loses determinism.

use std::collections::HashMap;

pub fn tally(votes: &[(u32, bool)]) -> usize {
    let mut by_peer: HashMap<u32, bool> = HashMap::new();
    for &(peer, up) in votes {
        by_peer.insert(peer, up);
    }
    by_peer.values().filter(|&&v| v).count()
}
