//! Known-good twin: exact small integers may cast (`as f64` is exact to
//! 2^53), and true floats go through the `to_bits` hex path
//! (`f64_to_json`), which round-trips bit-identically.

pub fn snapshot(round: usize, residual: f64) -> Vec<(&'static str, Json)> {
    vec![
        ("round", Json::Num(round as f64)),
        ("residual", f64_to_json(residual)),
    ]
}
