//! Known-bad: a true f64 serialized through `Json::Num` goes through
//! decimal formatting, and the reread checkpoint is no longer
//! bit-identical — the crash-recovery resume guarantee dies here.

pub fn snapshot_residual(residual: f64) -> Json {
    Json::Num(residual)
}
