//! Known-bad: raw-pointer arithmetic outside the blessed pool module.
//! Unsafe concurrency/aliasing lives in `linalg/pool.rs` only, where
//! Miri and TSan watch it.

pub fn sum_raw(v: &[f64]) -> f64 {
    let mut acc = 0.0;
    let p = v.as_ptr();
    for i in 0..v.len() {
        acc += unsafe { *p.add(i) };
    }
    acc
}
