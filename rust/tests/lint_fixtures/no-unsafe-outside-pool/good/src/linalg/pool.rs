//! Known-good twin: the same unsafe block inside `linalg/pool.rs`, the
//! one module sanctioned to hold it (and covered by the Miri/TSan CI
//! jobs).

pub fn sum_raw(v: &[f64]) -> f64 {
    let mut acc = 0.0;
    let p = v.as_ptr();
    for i in 0..v.len() {
        acc += unsafe { *p.add(i) };
    }
    acc
}
