//! Known-bad: materializing a d×d matrix in a sharded-plane module.
//! The entire point of the operator plane is that nothing n×n or d×d
//! ever exists; a square alloc here is the abstraction leaking.

use crate::linalg::Mat;

pub fn densify(d: usize) -> Mat {
    let out = Mat::zeros(d, d);
    out
}

pub fn probe(d: usize) -> Mat {
    Mat::eye(d)
}
