//! Known-good twin: rectangular panels are the sharded plane's native
//! shape, and test code may densify freely — the rule skips
//! `#[cfg(test)]` spans.

use crate::linalg::Mat;

pub fn panel(d: usize, r: usize) -> Mat {
    Mat::zeros(d, r)
}

pub fn workspace(rows: usize) -> Mat {
    Mat::zeros(rows, 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_pin() {
        let d = 6;
        let full = Mat::zeros(d, d);
        assert_eq!(full.rows(), d);
    }
}
