//! Known-bad: a function that constructs a wire `Message` without any
//! metering funnel in scope. Unmetered sends falsify the bytes axis of
//! every communication-cost figure.

pub fn broadcast_panel(panel: Vec<f64>, peers: &[u32]) -> Vec<(u32, Message)> {
    let mut out = Vec::new();
    for &p in peers {
        out.push((p, Message::Panel { data: panel.clone() }));
    }
    out
}
