//! Known-good twin: the three sanctioned shapes. Construction next to a
//! metering funnel, `match` arms that only *consume* messages, and
//! `let`-destructures that bind out of one.

pub fn send_panel(stats: &mut CommStats, panel: Vec<f64>, peer: u32) -> (u32, Message) {
    let msg = Message::Panel { data: panel };
    stats.record_up(wire_len(&msg));
    (peer, msg)
}

pub fn classify(msg: &Message) -> &'static str {
    match msg {
        Message::Panel { .. } => "panel",
        Message::Ack { .. } => "ack",
    }
}

pub fn unpack(msg: Message) -> Vec<f64> {
    let Message::Panel { data } = msg else { return Vec::new() };
    data
}
