//! Known-good twin: the same wall-clock read in an experiment driver,
//! outside the metered scope (`coordinator/{fault,rounds,protocol,
//! journal,reputation}.rs`, `align/`, `linalg/`) — timing the host is
//! exactly what a benchmark harness is for.

pub fn wall_ms<F: FnOnce()>(f: F) -> f64 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_secs_f64() * 1e3
}
