//! Known-bad: wall-clock reads inside a metered protocol path. Round
//! accounting must be driven by the simulated schedule, not host time,
//! or the rounds-vs-bytes frontier stops being reproducible.

pub fn round_elapsed_ms(start_ms: u64) -> u64 {
    let now = std::time::Instant::now();
    let _ = now;
    start_ms + 1
}
