//! Known-bad: float sort through `partial_cmp().unwrap()` panics on the
//! first NaN that reaches it.

pub fn sort_desc(v: &mut Vec<f64>) {
    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
}
