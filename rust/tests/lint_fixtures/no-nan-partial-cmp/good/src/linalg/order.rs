//! Known-good twin: `total_cmp` is total — NaN sorts to one end instead
//! of panicking, and the order is identical for NaN-free data.

pub fn sort_desc(v: &mut Vec<f64>) {
    v.sort_by(|a, b| b.total_cmp(a));
}
