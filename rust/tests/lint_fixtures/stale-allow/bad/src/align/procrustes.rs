//! Known-bad: suppressions that have rotted. An allow whose finding is
//! gone, an allow naming a rule that does not exist, and a directive
//! with no justification are all audit errors — suppressions are part
//! of the ledger, not a mute button.

// deigen-lint: allow(no-stray-threads) — the spawn this audited was removed two PRs ago
pub fn align(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

// deigen-lint: allow(no-wallclock) — typo: the rule id is no-wallclock-in-metered-paths
pub fn residual(a: &[f64]) -> f64 {
    a.iter().sum()
}

// deigen-lint: allow(no-stray-threads)
pub fn norm(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}
