//! Known-good twin: a live, justified suppression. The finding is still
//! reported (suppressed, with its reason — the ledger stays visible)
//! but the gate passes and the audit finds nothing stale.

pub fn legacy_background_sum(data: Vec<f64>) -> std::thread::JoinHandle<f64> {
    // deigen-lint: allow(no-stray-threads) — quarantined legacy path, scheduled for the pool migration
    std::thread::spawn(move || data.iter().sum())
}
