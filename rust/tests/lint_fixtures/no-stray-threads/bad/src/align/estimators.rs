//! Known-bad: an estimator spinning up its own thread. All parallelism
//! must go through `linalg::pool` so determinism and thread-count
//! control stay centralized.

pub fn sketch_in_background(data: Vec<f64>) -> std::thread::JoinHandle<f64> {
    std::thread::spawn(move || data.iter().sum())
}
