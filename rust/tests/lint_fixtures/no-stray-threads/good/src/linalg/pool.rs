//! Known-good twin: `linalg/pool.rs` is the one blessed home for thread
//! creation, so the same spawn is silent here.

pub fn start_worker(f: impl FnOnce() + Send + 'static) {
    std::thread::spawn(f);
}
