//! Known-good twin: the infallible fixed-width `try_into()` conversion
//! is exempt (a 4-byte slice into `[u8; 4]` cannot fail), errors flow
//! through `Result`, and tests may unwrap freely.

pub fn frame_len(header: &[u8]) -> Result<u32, String> {
    if header.len() < 4 {
        return Err("short header".to_string());
    }
    let word: [u8; 4] = header[0..4].try_into().expect("length checked above");
    Ok(u32::from_le_bytes(word))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_length() {
        assert_eq!(frame_len(&[7, 0, 0, 0]).unwrap(), 7);
    }
}
