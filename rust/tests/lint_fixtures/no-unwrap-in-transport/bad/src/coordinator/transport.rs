//! Known-bad: panicking extraction on the wire path. A malformed frame
//! from a faulty (or Byzantine) peer must surface as a typed transport
//! error the protocol can act on, never a leader panic.

pub fn frame_len(header: &[u8], fallback: Option<usize>) -> usize {
    if header.len() >= 4 {
        fallback.unwrap()
    } else {
        fallback.expect("no fallback length")
    }
}
