//! Durable crash-recovery integration suite (tier-1, DESIGN.md S17):
//!
//! 1. For every protocol kind, a run whose leader crashes after round R
//!    (`lcrash=R`) and is restarted from its journal produces a
//!    bit-identical estimate, per-round meter sequence, payload
//!    transcript, membership, and simulated time to the uninterrupted
//!    same-seed run — under a lossy + Byzantine fault plan, on both the
//!    in-process and the loopback-TCP engines.
//! 2. Recovery traffic (Resumed / Reseed / Reconnected) is metered as
//!    round-less control bytes only: it never touches the payload meters.
//! 3. Journal robustness: a corrupted or truncated tail falls back to the
//!    previous checkpoint (the crash re-fires on the replayed round and a
//!    second resume still converges to the same bits); wrong seed, wrong
//!    config, and a non-journal file are rejected with typed errors.
//! 4. The snapshot/restore contract round-trips bit-exactly under every
//!    protocol × codec pairing.

use std::path::PathBuf;
use std::sync::Arc;

use deigen::coordinator::fault::FaultAction;
use deigen::coordinator::{
    load_journal, run_cluster_faulty, run_cluster_journaled, run_cluster_resume,
    run_cluster_tcp_journaled, run_cluster_tcp_resume, ClusterConfig, CommSnapshot, FaultPlan,
    FaultRunConfig, FaultyClusterResult, JournalError, ProtocolKind, WireCodec, WorkerData,
};
use deigen::linalg::gemm::matmul;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;

const LOSSY_BYZ: &str = "drop=0.1, delay=0.2:10, dup=0.1, rto=5, byz=1:signflip";

fn noisy_observations(rng: &mut Pcg64, d: usize, r: usize, m: usize, noise: f64) -> Vec<Mat> {
    let q = rng.haar_orthogonal(d);
    let evs: Vec<f64> = (0..d).map(|i| if i < r { 1.0 } else { 0.3 }).collect();
    let x = matmul(&Mat::from_fn(d, d, |i, j| q[(i, j)] * evs[j]), &q.transpose());
    (0..m)
        .map(|_| {
            let mut e = rng.normal_mat(d, d).scale(noise);
            e.symmetrize();
            x.add(&e)
        })
        .collect()
}

fn mk_workers(obs: &[Mat]) -> Vec<WorkerData> {
    obs.iter().map(|o| WorkerData::dense(o.clone())).collect()
}

fn journal_path(tag: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("deigen_recovery_{}_{tag}.journal", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// The four protocol kinds, each configured for K=3 protocol rounds with
/// early stopping disabled so every run covers the full schedule.
fn protocol_kinds() -> Vec<(&'static str, ProtocolKind, usize)> {
    vec![
        ("oneshot", ProtocolKind::OneShot, 3),
        ("qpower", ProtocolKind::parse("qpower", 3, 0.0).unwrap(), 0),
        ("sanger", ProtocolKind::parse("sanger", 3, 0.0).unwrap(), 0),
        ("deepca", ProtocolKind::parse("deepca", 3, 0.0).unwrap(), 0),
    ]
}

fn config(kind: &ProtocolKind, refine: usize, seed: u64) -> ClusterConfig {
    ClusterConfig {
        r: 2,
        refine_rounds: refine,
        protocol: kind.clone(),
        codec: WireCodec::Int8,
        seed,
        ..Default::default()
    }
}

fn fault_config(spec: &str, seed: u64, m: usize) -> FaultRunConfig {
    FaultRunConfig {
        plan: FaultPlan::parse(spec).unwrap().seeded(seed),
        quorum: m - 1,
        grace_ms: 20.0,
        straggler_ms: 200.0,
    }
}

/// The acceptance predicate: everything the protocol computed matches
/// bit-for-bit; only the round-less recovery control traffic may differ.
fn assert_bit_identical(resumed: &FaultyClusterResult, base: &FaultyClusterResult, what: &str) {
    assert!(
        resumed.estimate.sub(&base.estimate).max_abs() == 0.0,
        "{what}: estimate bits diverge"
    );
    assert_eq!(resumed.per_round, base.per_round, "{what}: per-round meters diverge");
    assert_eq!(
        resumed.transcript.payload(),
        base.transcript.payload(),
        "{what}: payload transcripts diverge"
    );
    assert_eq!(resumed.in_quorum, base.in_quorum, "{what}: quorum membership diverges");
    assert_eq!(resumed.late_merged, base.late_merged, "{what}: late-merge set diverges");
    assert_eq!(resumed.lost, base.lost, "{what}: lost set diverges");
    assert_eq!(
        resumed.sim_time_s.to_bits(),
        base.sim_time_s.to_bits(),
        "{what}: simulated time diverges"
    );
    // totals: identical except the recovery control plane, which only a
    // crashed-and-resumed run carries (satellite: recovery is metered,
    // and metered as ctrl only)
    let normalized = CommSnapshot {
        bytes_ctrl: base.comm.bytes_ctrl,
        msgs_ctrl: base.comm.msgs_ctrl,
        ..resumed.comm
    };
    assert_eq!(normalized, base.comm, "{what}: payload totals diverge");
    assert!(
        resumed.comm.bytes_ctrl > base.comm.bytes_ctrl,
        "{what}: recovery control traffic was not metered"
    );
}

fn crashed(res: &FaultyClusterResult) -> bool {
    res.transcript.events.iter().any(|e| e.action == FaultAction::LeaderCrashed)
}

/// Core acceptance: crash at round 2 of 3, resume, finish bit-identically
/// — every protocol kind, in-process engine, lossy + Byzantine plan.
#[test]
fn crashed_and_resumed_runs_are_bit_identical_inproc() {
    let (d, m, seed) = (16usize, 6usize, 11u64);
    let mut rng = Pcg64::seed(seed);
    let obs = noisy_observations(&mut rng, d, 2, m, 0.05);
    for (name, kind, refine) in protocol_kinds() {
        let cfg = config(&kind, refine, seed);
        let base_fc = fault_config(LOSSY_BYZ, seed, m);
        let crash_fc = fault_config(&format!("{LOSSY_BYZ}, lcrash=2"), seed, m);
        let base =
            run_cluster_faulty(mk_workers(&obs), Arc::new(NativeEngine::default()), &cfg, &base_fc);
        assert!(!crashed(&base), "{name}: uninterrupted run reports a crash");

        let path = journal_path(&format!("inproc_{name}"));
        let partial = run_cluster_journaled(
            mk_workers(&obs),
            Arc::new(NativeEngine::default()),
            &cfg,
            &crash_fc,
            &path,
        )
        .expect("journaled run failed");
        assert!(crashed(&partial), "{name}: lcrash=2 did not crash the leader");
        assert!(
            partial.per_round.len() < base.per_round.len(),
            "{name}: crashed run finished every round"
        );
        // the journal holds checkpoints for rounds 0..=2 (crash after 2)
        let loaded = load_journal(&path).expect("journal unreadable after crash");
        assert_eq!(loaded.records.len(), 3, "{name}: unexpected checkpoint count");
        assert!(!loaded.truncated, "{name}: clean journal reported a damaged tail");

        let resumed = run_cluster_resume(
            mk_workers(&obs),
            Arc::new(NativeEngine::default()),
            &cfg,
            &crash_fc,
            &path,
        )
        .expect("resume failed");
        assert!(!crashed(&resumed), "{name}: resumed run crashed again");
        assert_bit_identical(&resumed, &base, name);
        // the resumed leader kept journaling: one checkpoint per round
        let finished = load_journal(&path).expect("journal unreadable after resume");
        assert_eq!(finished.records.len(), 4, "{name}: resumed run stopped journaling");
        let _ = std::fs::remove_file(&path);
    }
}

/// The same acceptance over real loopback sockets: the TCP leader
/// checkpoints between rounds, dies without `Done` frames (workers see
/// EOF), and a restarted leader + reconnecting workers finish on exactly
/// the bits of the uninterrupted in-process run.
#[test]
fn crashed_and_resumed_runs_are_bit_identical_tcp() {
    let Ok(probe) = std::net::TcpListener::bind("127.0.0.1:0") else {
        eprintln!("skipping: loopback unavailable");
        return;
    };
    drop(probe);
    let (d, m, seed) = (16usize, 5usize, 19u64);
    let mut rng = Pcg64::seed(seed);
    let obs = noisy_observations(&mut rng, d, 2, m, 0.05);
    for (name, kind, refine) in protocol_kinds() {
        let cfg = config(&kind, refine, seed);
        let base_fc = fault_config(LOSSY_BYZ, seed, m);
        let crash_fc = fault_config(&format!("{LOSSY_BYZ}, lcrash=2"), seed, m);
        // the in-process uninterrupted run is the cross-engine oracle
        let base =
            run_cluster_faulty(mk_workers(&obs), Arc::new(NativeEngine::default()), &cfg, &base_fc);

        let path = journal_path(&format!("tcp_{name}"));
        let partial = run_cluster_tcp_journaled(
            mk_workers(&obs),
            Arc::new(NativeEngine::default()),
            &cfg,
            &crash_fc,
            &path,
        )
        .expect("TCP journaled run failed");
        assert!(crashed(&partial), "{name}: TCP lcrash=2 did not crash the leader");

        let resumed = run_cluster_tcp_resume(
            mk_workers(&obs),
            Arc::new(NativeEngine::default()),
            &cfg,
            &crash_fc,
            &path,
        )
        .expect("TCP resume failed");
        assert_bit_identical(&resumed, &base, name);
        let _ = std::fs::remove_file(&path);
    }
}

/// A crashed TCP run and a crashed in-process run journal identical
/// checkpoints — byte-for-byte — so a journal written by one engine
/// resumes on the other.
#[test]
fn journals_are_byte_identical_across_engines_and_interchangeable() {
    let Ok(probe) = std::net::TcpListener::bind("127.0.0.1:0") else {
        eprintln!("skipping: loopback unavailable");
        return;
    };
    drop(probe);
    let (d, m, seed) = (16usize, 5usize, 7u64);
    let mut rng = Pcg64::seed(seed);
    let obs = noisy_observations(&mut rng, d, 2, m, 0.05);
    let kind = ProtocolKind::parse("qpower", 3, 0.0).unwrap();
    let cfg = config(&kind, 0, seed);
    let base_fc = fault_config(LOSSY_BYZ, seed, m);
    let crash_fc = fault_config(&format!("{LOSSY_BYZ}, lcrash=2"), seed, m);
    let base =
        run_cluster_faulty(mk_workers(&obs), Arc::new(NativeEngine::default()), &cfg, &base_fc);

    let p_in = journal_path("xengine_inproc");
    let p_tcp = journal_path("xengine_tcp");
    run_cluster_journaled(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &cfg,
        &crash_fc,
        &p_in,
    )
    .expect("journaled run failed");
    run_cluster_tcp_journaled(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &cfg,
        &crash_fc,
        &p_tcp,
    )
    .expect("TCP journaled run failed");
    let bytes_in = std::fs::read(&p_in).unwrap();
    let bytes_tcp = std::fs::read(&p_tcp).unwrap();
    assert_eq!(bytes_in, bytes_tcp, "the two engines journal different bytes");

    // cross-resume: the TCP-written journal drives an in-process resume
    let resumed = run_cluster_resume(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &cfg,
        &crash_fc,
        &p_tcp,
    )
    .expect("cross-engine resume failed");
    assert_bit_identical(&resumed, &base, "cross-engine");
    let _ = std::fs::remove_file(&p_in);
    let _ = std::fs::remove_file(&p_tcp);
}

/// A damaged tail is not fatal: resume falls back to the checkpoint
/// before it, the scheduled crash re-fires on the replayed round (and is
/// journaled again), and a second resume completes — still bit-identical.
#[test]
fn corrupt_tail_falls_back_to_previous_checkpoint_and_recovers() {
    let (d, m, seed) = (16usize, 6usize, 29u64);
    let mut rng = Pcg64::seed(seed);
    let obs = noisy_observations(&mut rng, d, 2, m, 0.05);
    let kind = ProtocolKind::parse("deepca", 3, 0.0).unwrap();
    let cfg = config(&kind, 0, seed);
    let base_fc = fault_config(LOSSY_BYZ, seed, m);
    let crash_fc = fault_config(&format!("{LOSSY_BYZ}, lcrash=2"), seed, m);
    let base =
        run_cluster_faulty(mk_workers(&obs), Arc::new(NativeEngine::default()), &cfg, &base_fc);

    let path = journal_path("corrupt_tail");
    run_cluster_journaled(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &cfg,
        &crash_fc,
        &path,
    )
    .expect("journaled run failed");

    // flip one byte near the end: the round-2 checkpoint no longer
    // validates and must be dropped, not trusted
    let mut bytes = std::fs::read(&path).unwrap();
    let n = bytes.len();
    bytes[n - 9] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();
    let loaded = load_journal(&path).expect("corrupt tail should load with truncation");
    assert!(loaded.truncated, "corruption not detected");
    assert_eq!(loaded.records.len(), 2, "expected fallback to the round-1 checkpoint");

    // resume from round 1 replays round 2, where lcrash=2 fires again
    let again = run_cluster_resume(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &cfg,
        &crash_fc,
        &path,
    )
    .expect("resume over corrupt tail failed");
    assert!(crashed(&again), "replayed round did not re-fire the scheduled crash");

    // ... after which the journal is whole again and a second resume
    // finishes the run on the original bits
    let resumed = run_cluster_resume(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &cfg,
        &crash_fc,
        &path,
    )
    .expect("second resume failed");
    assert!(resumed.estimate.sub(&base.estimate).max_abs() == 0.0, "estimate bits diverge");
    assert_eq!(resumed.per_round, base.per_round, "per-round meters diverge");
    assert_eq!(resumed.transcript.payload(), base.transcript.payload());
    let _ = std::fs::remove_file(&path);
}

/// Structural rejections are typed: wrong seed, wrong config, and a file
/// that is not a journal each name their failure exactly.
#[test]
fn mismatched_or_garbage_journals_are_rejected_with_typed_errors() {
    let (d, m, seed) = (16usize, 5usize, 31u64);
    let mut rng = Pcg64::seed(seed);
    let obs = noisy_observations(&mut rng, d, 2, m, 0.05);
    let kind = ProtocolKind::parse("qpower", 3, 0.0).unwrap();
    let cfg = config(&kind, 0, seed);
    let crash_fc = fault_config("lcrash=1", seed, m);
    let path = journal_path("typed_errors");
    run_cluster_journaled(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &cfg,
        &crash_fc,
        &path,
    )
    .expect("journaled run failed");

    // wrong seed: both the plan hashes and the rng streams would differ
    let wrong_seed = ClusterConfig { seed: seed + 1, ..cfg.clone() };
    let err = run_cluster_resume(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &wrong_seed,
        &fault_config("lcrash=1", seed, m),
        &path,
    )
    .unwrap_err();
    assert!(
        matches!(err, JournalError::SeedMismatch { got, want } if got == seed && want == seed + 1),
        "expected SeedMismatch, got {err:?}"
    );

    // wrong config (codec changes the wire bits): fingerprint mismatch
    let wrong_codec = ClusterConfig { codec: WireCodec::F64, ..cfg.clone() };
    let err = run_cluster_resume(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &wrong_codec,
        &crash_fc,
        &path,
    )
    .unwrap_err();
    assert!(
        matches!(err, JournalError::ConfigMismatch { .. }),
        "expected ConfigMismatch, got {err:?}"
    );

    // not a journal at all
    let garbage = journal_path("garbage");
    std::fs::write(&garbage, b"not a journal, definitely").unwrap();
    let err = run_cluster_resume(
        mk_workers(&obs),
        Arc::new(NativeEngine::default()),
        &cfg,
        &crash_fc,
        &garbage,
    )
    .unwrap_err();
    assert!(matches!(err, JournalError::BadMagic), "expected BadMagic, got {err:?}");

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&garbage);
}

/// The snapshot/restore contract round-trips under every protocol ×
/// codec pairing: whatever panel bits the codec produced are exactly the
/// bits the journal reproduces, so crash + resume is bit-identical for
/// each combination (clean plan except the crash — the serialization is
/// what is under test here; the lossy+byz leg is covered above).
#[test]
fn journal_round_trips_across_protocols_and_codecs() {
    let (d, m, seed) = (16usize, 5usize, 41u64);
    let mut rng = Pcg64::seed(seed);
    let obs = noisy_observations(&mut rng, d, 2, m, 0.05);
    for (name, kind, refine) in protocol_kinds() {
        for codec in [WireCodec::F64, WireCodec::Int8, WireCodec::FdSketch { l: 4 }] {
            let cfg = ClusterConfig { codec, ..config(&kind, refine, seed) };
            let fc = fault_config("lcrash=2", seed, m);
            let base_fc = FaultRunConfig { plan: FaultPlan::none().seeded(seed), ..fc.clone() };
            let base = run_cluster_faulty(
                mk_workers(&obs),
                Arc::new(NativeEngine::default()),
                &cfg,
                &base_fc,
            );
            let tag = format!("rt_{name}_{}", codec.name());
            let path = journal_path(&tag);
            run_cluster_journaled(
                mk_workers(&obs),
                Arc::new(NativeEngine::default()),
                &cfg,
                &fc,
                &path,
            )
            .expect("journaled run failed");
            let resumed = run_cluster_resume(
                mk_workers(&obs),
                Arc::new(NativeEngine::default()),
                &cfg,
                &fc,
                &path,
            )
            .expect("resume failed");
            let what = format!("{name}/{}", codec.name());
            assert!(
                resumed.estimate.sub(&base.estimate).max_abs() == 0.0,
                "{what}: estimate bits diverge"
            );
            assert_eq!(resumed.per_round, base.per_round, "{what}: per-round meters diverge");
            assert_eq!(
                resumed.transcript.payload(),
                base.transcript.payload(),
                "{what}: payload transcripts diverge"
            );
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// Journaling a run that never crashes is a no-op for the results: same
/// bits with or without `--journal`, and the finished journal replays
/// (checkpoint per round, clean tail). Also covers the clean-plan case.
#[test]
fn journaling_without_a_crash_changes_nothing() {
    let (d, m, seed) = (16usize, 5usize, 37u64);
    let mut rng = Pcg64::seed(seed);
    let obs = noisy_observations(&mut rng, d, 2, m, 0.05);
    let kind = ProtocolKind::parse("sanger", 3, 0.0).unwrap();
    let cfg = config(&kind, 0, seed);
    let fc = FaultRunConfig::full(m);
    let base = run_cluster_faulty(mk_workers(&obs), Arc::new(NativeEngine::default()), &cfg, &fc);
    let path = journal_path("no_crash");
    let journaled =
        run_cluster_journaled(mk_workers(&obs), Arc::new(NativeEngine::default()), &cfg, &fc, &path)
            .expect("journaled run failed");
    assert!(journaled.estimate.sub(&base.estimate).max_abs() == 0.0);
    assert_eq!(journaled.comm, base.comm);
    assert_eq!(journaled.transcript, base.transcript);
    let loaded = load_journal(&path).expect("finished journal unreadable");
    assert_eq!(loaded.records.len(), 4, "checkpoints for rounds 0..=3");
    assert!(!loaded.truncated);
    let _ = std::fs::remove_file(&path);
}
