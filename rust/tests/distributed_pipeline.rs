//! End-to-end pipeline integration tests: threaded cluster vs library
//! estimators, communication-accounting invariants, failure injection, and
//! the application pipelines (embeddings, sensing) wired through the
//! coordinator.

use std::sync::Arc;

use deigen::align;
use deigen::coordinator::{
    run_cluster, AggregationRule, ClusterConfig, NetworkModel, NodeBehavior, Shard,
    WireCodec, WorkerData,
};
use deigen::linalg::subspace::dist2;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;
use deigen::synth::{CovModel, SpectrumModel};
use deigen::testkit::{check, tol};

fn pca_workers(
    seed: u64,
    d: usize,
    r: usize,
    m: usize,
    n: usize,
) -> (Mat, Vec<WorkerData>) {
    let mut rng = Pcg64::seed(seed);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    let workers = (0..m)
        .map(|i| {
            WorkerData::dense(CovModel::empirical_cov(&cov.sample(n, &mut rng.split(i as u64))))
        })
        .collect();
    (cov.principal_subspace(), workers)
}

/// Like [`pca_workers`] but the workers keep their raw sample shards —
/// the matrix-free Gram data plane.
fn pca_sample_workers(
    seed: u64,
    d: usize,
    r: usize,
    m: usize,
    n: usize,
) -> (Mat, Vec<WorkerData>) {
    let mut rng = Pcg64::seed(seed);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    let workers = (0..m)
        .map(|i| WorkerData::samples(cov.sample(n, &mut rng.split(i as u64))))
        .collect();
    (cov.principal_subspace(), workers)
}

#[test]
fn cluster_single_round_equals_library_algorithm1() {
    let (truth, workers) = pca_workers(1, 40, 4, 10, 300);
    let cfg = ClusterConfig { r: 4, seed: 3, ..Default::default() };
    let res = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
    let lib = align::procrustes_fix(&res.local_panels);
    check::assert_close(&res.estimate, &lib, 1e-10, "cluster vs library Alg1");
    check::assert_orthonormal(&res.estimate, tol::FACTOR, "cluster estimate");
    assert!(dist2(&res.estimate, &truth) < 0.15);
    // metric cross-check: production dist2 vs the definition-level oracle
    let oracle_dist = check::sin_theta(&res.estimate, &truth);
    assert!((dist2(&res.estimate, &truth) - oracle_dist).abs() < tol::ITER);
}

/// Clone the dense observations back out of a worker set (test helper for
/// same-data reruns).
fn dense_obs(workers: &[WorkerData]) -> Vec<Mat> {
    workers
        .iter()
        .map(|w| match &w.shard {
            Shard::Dense(c) => c.clone(),
            Shard::Samples(x) => x.clone(),
        })
        .collect()
}

#[test]
fn refinement_improves_or_matches_single_round() {
    let (truth, workers) = pca_workers(2, 40, 4, 12, 120);
    let obs: Vec<Mat> = dense_obs(&workers);
    let cfg0 = ClusterConfig { r: 4, seed: 5, ..Default::default() };
    let r0 = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg0);
    let workers2: Vec<WorkerData> = obs.into_iter().map(WorkerData::dense).collect();
    let cfg2 = ClusterConfig { r: 4, refine_rounds: 3, seed: 5, ..Default::default() };
    let r2 = run_cluster(workers2, Arc::new(NativeEngine::default()), &cfg2);
    let d0 = dist2(&r0.estimate, &truth);
    let d2 = dist2(&r2.estimate, &truth);
    assert!(d2 <= d0 + 0.03, "refined {d2} vs single {d0}");
}

/// The sample-sharded data plane end to end: workers own raw (n, d)
/// shards, local solves run matrix-free through the Gram operator, and
/// the single-round estimate matches both the truth and a dense-plane run
/// on the materialized covariances of the same samples.
#[test]
fn sample_sharded_cluster_matches_dense_plane_and_truth() {
    let (truth, sharded) = pca_sample_workers(12, 40, 4, 10, 300);
    let dense: Vec<WorkerData> = sharded
        .iter()
        .map(|w| match &w.shard {
            Shard::Samples(x) => {
                WorkerData::dense(CovModel::empirical_cov(x))
            }
            Shard::Dense(_) => unreachable!("sample workers requested"),
        })
        .collect();
    let cfg = ClusterConfig { r: 4, seed: 3, ..Default::default() };
    let res_s = run_cluster(sharded, Arc::new(NativeEngine::default()), &cfg);
    let res_d = run_cluster(dense, Arc::new(NativeEngine::default()), &cfg);
    check::assert_orthonormal(&res_s.estimate, tol::FACTOR, "sharded estimate");
    assert!(dist2(&res_s.estimate, &truth) < 0.15);
    assert!(
        dist2(&res_s.estimate, &res_d.estimate) < tol::ITER,
        "data planes disagree: {}",
        dist2(&res_s.estimate, &res_d.estimate)
    );
    // same protocol shape and wire volume: panels, not shards, cross the wire
    assert_eq!(res_s.comm, res_d.comm);
}

#[test]
fn communication_scales_linearly_in_m_single_round() {
    let mut per_node = Vec::new();
    for &m in &[4usize, 8, 16] {
        let (_, workers) = pca_workers(3, 32, 4, m, 100);
        let cfg = ClusterConfig { r: 4, seed: 1, ..Default::default() };
        let res = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
        per_node.push(res.comm.bytes_up as f64 / m as f64);
        assert_eq!(res.comm.rounds, 1);
    }
    // per-node upload must be independent of m (the single-round property)
    assert!((per_node[0] - per_node[2]).abs() < 1e-9, "{per_node:?}");
}

#[test]
fn refinement_comm_scales_with_rounds() {
    let mut totals = Vec::new();
    for &k in &[1usize, 2, 4] {
        let (_, workers) = pca_workers(4, 32, 4, 6, 100);
        let cfg = ClusterConfig { r: 4, refine_rounds: k, seed: 1, ..Default::default() };
        let res = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
        assert_eq!(res.comm.rounds, 1 + k);
        totals.push(res.comm.bytes_up + res.comm.bytes_down);
    }
    assert!(totals[0] < totals[1] && totals[1] < totals[2]);
}

#[test]
fn wan_simulated_time_dominated_by_latency_per_round() {
    let (_, workers) = pca_workers(5, 32, 4, 8, 100);
    let cfg = ClusterConfig {
        r: 4,
        refine_rounds: 4,
        network: NetworkModel::wan(),
        seed: 1,
        ..Default::default()
    };
    let res = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
    // 5 rounds x 50 ms = 250 ms of pure latency; bytes add a little more
    assert!(res.sim_time_s >= 0.25, "{}", res.sim_time_s);
    assert!(res.sim_time_s < 1.0);
}

#[test]
fn byzantine_majority_attack_defeats_mean_but_not_median_reference() {
    // 5 of 16 byzantine: mean aggregation degrades noticeably more than
    // coordinate-median aggregation
    let (truth, mut workers) = pca_workers(6, 40, 3, 16, 400);
    for w in workers.iter_mut().skip(1).take(5) {
        w.behavior = NodeBehavior::Byzantine;
    }
    let obs: Vec<(Mat, NodeBehavior)> = workers
        .iter()
        .zip(dense_obs(&workers))
        .map(|(w, o)| (o, w.behavior))
        .collect();
    let cfg_mean = ClusterConfig { r: 3, seed: 2, ..Default::default() };
    let res_mean = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg_mean);

    let workers2: Vec<WorkerData> = obs
        .into_iter()
        .map(|(o, b)| WorkerData { shard: Shard::Dense(o), behavior: b })
        .collect();
    let cfg_med = ClusterConfig {
        r: 3,
        aggregation: AggregationRule::CoordinateMedian,
        seed: 2,
        ..Default::default()
    };
    let res_med = run_cluster(workers2, Arc::new(NativeEngine::default()), &cfg_med);

    let dm = dist2(&res_mean.estimate, &truth);
    let dr = dist2(&res_med.estimate, &truth);
    assert!(dr < dm, "median {dr} should beat mean {dm} under attack");
    assert!(dr < 0.25, "median should stay accurate: {dr}");
}

#[test]
fn estimates_always_orthonormal_across_configs() {
    for seed in 0..6u64 {
        let mut rng = Pcg64::seed(7000 + seed);
        let d = 16 + rng.next_below(40);
        let r = 1 + rng.next_below(5.min(d / 3));
        let m = 2 + rng.next_below(10);
        let (_, workers) = pca_workers(seed + 10, d, r, m, 150);
        let cfg = ClusterConfig {
            r,
            refine_rounds: rng.next_below(3),
            seed,
            ..Default::default()
        };
        let res = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
        check::assert_orthonormal(
            &res.estimate,
            1e-7,
            &format!("seed {seed} d={d} r={r} m={m}"),
        );
    }
}

#[test]
fn int8_wire_codec_cuts_upload_8x_within_stat_tolerance() {
    // the compressed-protocol acceptance pin: on the same seed and
    // observations, Int8 transport reports bytes_up at most 1/6 of the
    // raw-f64 run (the actual ratio is ~8x minus headers), while the
    // single-round estimate's sin-theta to ground truth stays within
    // tol::STAT of the uncompressed estimate's
    let (truth, workers) = pca_workers(8, 48, 4, 10, 300);
    let obs: Vec<Mat> = dense_obs(&workers);
    let cfg64 = ClusterConfig { r: 4, seed: 21, ..Default::default() };
    let r64 = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg64);
    let workers2: Vec<WorkerData> = obs.into_iter().map(WorkerData::dense).collect();
    let cfg8 = ClusterConfig { r: 4, codec: WireCodec::Int8, seed: 21, ..Default::default() };
    let r8 = run_cluster(workers2, Arc::new(NativeEngine::default()), &cfg8);

    assert!(
        6 * r8.comm.bytes_up <= r64.comm.bytes_up,
        "int8 bytes_up {} not <= 1/6 of f64 {}",
        r8.comm.bytes_up,
        r64.comm.bytes_up
    );
    // fewer bytes -> strictly less simulated time on a finite-bandwidth link
    assert!(r8.sim_time_s < r64.sim_time_s);
    let (d8, d64) = (dist2(&r8.estimate, &truth), dist2(&r64.estimate, &truth));
    assert!((d8 - d64).abs() <= tol::STAT, "int8 {d8} vs f64 {d64}");
    check::assert_orthonormal(&r8.estimate, tol::FACTOR, "int8 estimate");
    // the metric itself cross-checked against the definition-level oracle
    assert!((d8 - check::sin_theta(&r8.estimate, &truth)).abs() < tol::ITER);
    // identical protocol shape: compression changes bytes, not rounds
    assert_eq!(r8.comm.rounds, r64.comm.rounds);
    assert_eq!(r8.comm.msgs_up, r64.comm.msgs_up);
}

#[test]
fn codec_sweep_preserves_single_round_accuracy_ordering() {
    // f16 is near-lossless and fd with l > r is span-exact on the wire;
    // every codec keeps the single-round estimate orthonormal and close
    // to the f64 estimate
    let (truth, workers) = pca_workers(9, 40, 4, 8, 300);
    let obs: Vec<Mat> = dense_obs(&workers);
    let cfg = ClusterConfig { r: 4, seed: 33, ..Default::default() };
    let base = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
    let d_base = dist2(&base.estimate, &truth);
    for codec in [WireCodec::F16, WireCodec::Int8, WireCodec::FdSketch { l: 6 }] {
        let ws: Vec<WorkerData> = obs.iter().map(|o| WorkerData::dense(o.clone())).collect();
        let cfg = ClusterConfig { r: 4, codec, seed: 33, ..Default::default() };
        let res = run_cluster(ws, Arc::new(NativeEngine::default()), &cfg);
        check::assert_orthonormal(&res.estimate, 1e-7, &codec.name());
        let d = dist2(&res.estimate, &truth);
        assert!((d - d_base).abs() <= tol::STAT, "{}: {d} vs f64 {d_base}", codec.name());
        assert!(res.comm.bytes_up <= base.comm.bytes_up, "{} grew the upload", codec.name());
    }
}

#[test]
fn sensing_pipeline_through_coordinator() {
    // quadratic sensing local D matrices as worker observations
    let mut rng = Pcg64::seed(42);
    let (d, r, m, n) = (40usize, 2usize, 12usize, 12 * 40 * 2);
    let inst = deigen::sensing::SensingInstance::draw(d, r, 0.0, &mut rng);
    let workers: Vec<WorkerData> = (0..m)
        .map(|i| {
            let mut node_rng = rng.split(i as u64);
            let (a, y) = inst.measure(n, &mut node_rng);
            WorkerData::dense(deigen::sensing::spectral_matrix(&a, &y))
        })
        .collect();
    let cfg = ClusterConfig { r, refine_rounds: 5, seed: 9, ..Default::default() };
    let res = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
    let leak = inst.leakage(&res.estimate);
    assert!(leak < 0.5, "distributed sensing init too weak: {leak}");
}

#[test]
fn embeddings_alignment_stays_near_central_embedding() {
    let mut rng = Pcg64::seed(77);
    let g = deigen::graph::sbm(100, 2, 0.3, 0.03, &mut rng);
    let z_central = deigen::graph::hope_embedding(&g, 8, 0.02);
    let locals: Vec<Mat> = (0..8)
        .map(|_| deigen::graph::hope_embedding(&g.censor(0.1, &mut rng), 8, 0.02))
        .collect();
    let mut acc = Mat::zeros(100, 8);
    for z in &locals {
        acc.axpy(
            1.0 / 8.0,
            &deigen::linalg::procrustes::procrustes_align(z, &locals[0]),
        );
    }
    let aligned = deigen::linalg::procrustes::procrustes_align(&acc, &z_central);
    let rel = aligned.sub(&z_central).fro_norm() / z_central.fro_norm();
    assert!(rel < 0.4, "aligned embedding too far from central: {rel}");
}
