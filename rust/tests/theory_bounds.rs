//! Empirical verification of the paper's theory on instances satisfying
//! Assumption 1 — the deterministic Theorem 2 bound, the Lemma-3
//! path-independence property, and the Theorem-3/4 statistical behaviour.
//! These are randomized property tests (hand-rolled; proptest is not
//! available offline): each runs many seeded instances and checks the
//! claimed inequality with an explicit constant. All norms entering the
//! bounds are computed by the testkit's independent Jacobi oracle, so the
//! theory checks don't lean on the production SVD they indirectly test.

use deigen::align;
use deigen::linalg::gemm::matmul;
use deigen::linalg::procrustes::procrustes_align;
use deigen::linalg::subspace::dist2;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::{LocalSolver, NativeEngine};
use deigen::synth::{CovModel, SpectrumModel};
use deigen::testkit::{check, oracle, tol};

/// Spectral norm through the oracle route (Jacobi on A^T A).
fn spectral_norm(a: &Mat) -> f64 {
    oracle::spectral_norm(a)
}

/// Build an Assumption-1 instance: symmetric X with eigengap delta at rank
/// r, plus m symmetric perturbations with ||E^i||_2 < delta/8.
fn assumption1_instance(
    rng: &mut Pcg64,
    d: usize,
    r: usize,
    delta: f64,
    m: usize,
    noise: f64,
) -> (Mat, Mat, Vec<Mat>) {
    assert!(noise < delta / 8.0);
    let q = rng.haar_orthogonal(d);
    let evs: Vec<f64> = (0..d)
        .map(|i| if i < r { 1.0 } else { 1.0 - delta - 0.01 * (i - r) as f64 / d as f64 })
        .collect();
    let x = matmul(&Mat::from_fn(d, d, |i, j| q[(i, j)] * evs[j]), &q.transpose());
    let truth = q.col_block(0, r);
    let hats: Vec<Mat> = (0..m)
        .map(|_| {
            // symmetric noise scaled to spectral norm ~ noise
            let mut e = rng.normal_mat(d, d);
            e.symmetrize();
            let s = spectral_norm(&e);
            x.add(&e.scale(noise / s))
        })
        .collect();
    (x, truth, hats)
}

/// Theorem 2: dist2(Alg1 output, V1) <= C * (max_i ||E^i||^2 / delta^2
///                                          + ||mean E^i|| / delta).
#[test]
fn theorem2_bound_holds_empirically() {
    let solver = NativeEngine::default();
    for seed in 0..8u64 {
        let mut rng = Pcg64::seed(100 + seed);
        let (d, r, delta, m) = (40, 3, 0.4, 12);
        let noise = 0.04; // < delta/8 = 0.05
        let (x, truth, hats) = assumption1_instance(&mut rng, d, r, delta, m, noise);

        let panels: Vec<Mat> = hats
            .iter()
            .map(|h| solver.leading_subspace(h, r, &mut rng))
            .collect();
        let est = align::procrustes_fix(&panels);
        let err = dist2(&est, &truth);

        let max_e = hats
            .iter()
            .map(|h| spectral_norm(&h.sub(&x)))
            .fold(0.0f64, f64::max);
        let mut mean = Mat::zeros(d, d);
        for h in &hats {
            mean.axpy(1.0 / m as f64, h);
        }
        let mean_e = spectral_norm(&mean.sub(&x));
        let bound = max_e * max_e / (delta * delta) + mean_e / delta;
        // the paper's <~ hides a modest universal constant; C = 8 is generous
        assert!(
            err <= 8.0 * bound,
            "seed {seed}: err {err} vs bound {bound}"
        );
    }
}

/// Lemma 3 / Stewart path independence: aligning with a good local
/// reference is equivalent to aligning with V1 up to quadratic error.
#[test]
fn lemma3_reference_vs_truth_alignment_quadratic() {
    for &noise in &[0.01f64, 0.02, 0.04] {
        let mut rng = Pcg64::seed(7);
        let solver = NativeEngine::default();
        let (d, r, delta, m) = (30, 2, 0.4, 6);
        let (_, truth, hats) = assumption1_instance(&mut rng, d, r, delta, m, noise);
        let panels: Vec<Mat> = hats
            .iter()
            .map(|h| solver.leading_subspace(h, r, &mut rng))
            .collect();
        // align panel 1 against (a) panel 0 and (b) the truth basis; the
        // two results should differ by O(noise^2/delta^2)
        let via_ref = procrustes_align(&panels[1], &panels[0]);
        // "ideal" alignment target: truth rotated to match panel 0 (the
        // canonical choice of V1 in Eq. (8))
        let v1 = procrustes_align(&truth, &panels[0]);
        let via_truth = procrustes_align(&panels[1], &v1);
        let gap = via_ref.sub(&via_truth).max_abs();
        let quad = (noise / delta) * (noise / delta);
        assert!(
            gap <= 30.0 * quad + 1e-9,
            "noise {noise}: gap {gap} vs quad {quad}"
        );
    }
}

/// Theorem 3 statistical shape: error decays ~ 1/sqrt(n) with everything
/// else fixed, and Alg 1 stays within a constant of the centralized rate.
#[test]
fn theorem3_error_decay_and_centralized_match() {
    let model = SpectrumModel::M1 { r: 4, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let mut errs = Vec::new();
    for &n in &[100usize, 400, 1600] {
        let mut trial_errs = Vec::new();
        for t in 0..3u64 {
            let mut rng = Pcg64::seed(500 + n as u64 + t);
            let cov = CovModel::draw(&model, 50, &mut rng);
            let set = deigen::experiments::common::EstimatorSet::default();
            let e = deigen::experiments::common::pca_trial(&cov, 10, n, set, &mut rng);
            trial_errs.push((e.algo1, e.central));
        }
        let a1: f64 = trial_errs.iter().map(|p| p.0).sum::<f64>() / 3.0;
        let c: f64 = trial_errs.iter().map(|p| p.1).sum::<f64>() / 3.0;
        assert!(a1 <= 3.0 * c + 0.02, "n={n}: alg1 {a1} central {c}");
        errs.push(a1);
    }
    // quadrupling n should roughly halve the error; allow slack
    assert!(errs[1] < 0.75 * errs[0], "{errs:?}");
    assert!(errs[2] < 0.75 * errs[1], "{errs:?}");
}

/// The Garber-et-al lower-bound phenomenon: naive averaging stalls at
/// Omega(1) error while sign-fixing tracks 1/sqrt(mn) — the r = 1 story
/// that motivates the whole paper.
#[test]
fn naive_averaging_stalls_sign_fixing_does_not() {
    let model = SpectrumModel::M1 { r: 1, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let solver = NativeEngine::default();
    let mut rng = Pcg64::seed(900);
    let cov = CovModel::draw(&model, 40, &mut rng);
    let truth = cov.principal_subspace();
    let m = 24;
    let n = 800;
    let panels: Vec<Mat> = (0..m)
        .map(|i| {
            let mut node_rng = rng.split(i as u64 + 1);
            let x = cov.sample(n, &mut node_rng);
            let mut v = solver.leading_subspace(
                &CovModel::empirical_cov(&x),
                1,
                &mut node_rng,
            );
            // adversarial-but-valid sign flips: half the machines return -v
            if i % 2 == 0 {
                v = v.scale(-1.0);
            }
            v
        })
        .collect();
    let naive = dist2(&align::naive_average(&panels), &truth);
    let fixed = dist2(&align::sign_fix_average(&panels), &truth);
    assert!(naive > 0.5, "naive should stall: {naive}");
    assert!(fixed < 0.1, "sign fixing should recover: {fixed}");
}

/// Rotation-equivariance property: feeding the cluster rotated copies of
/// the same subspace yields the same subspace — over many random seeds.
#[test]
fn property_alignment_subspace_equivariance() {
    for seed in 0..20u64 {
        let mut rng = Pcg64::seed(2000 + seed);
        let d = 10 + (rng.next_below(30));
        let r = 1 + rng.next_below(4.min(d / 2));
        let truth = rng.haar_stiefel(d, r);
        let m = 3 + rng.next_below(8);
        let panels: Vec<Mat> = (0..m)
            .map(|_| {
                let z = rng.haar_orthogonal(r);
                deigen::linalg::qr::orthonormalize(
                    &matmul(&truth, &z).add(&rng.normal_mat(d, r).scale(0.02)),
                )
            })
            .collect();
        let est = align::procrustes_fix(&panels);
        assert!(
            dist2(&est, &truth) < 0.15,
            "seed {seed} d={d} r={r} m={m}: {}",
            dist2(&est, &truth)
        );
        check::assert_orthonormal(
            &est,
            tol::FACTOR,
            &format!("seed {seed} d={d} r={r} m={m}"),
        );
    }
}
