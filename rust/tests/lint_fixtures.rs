//! Drives the fixture corpus under `tests/lint_fixtures/`: every rule
//! must *fire* on its known-bad snippet (and only that rule) and stay
//! *silent* on the known-good twin. This is the proof that the gate in
//! `lint_clean.rs` is load-bearing — a rule that never fires would pass
//! the tree trivially.

use std::fs;
use std::path::Path;

use deigen::lintpass::rules;
use deigen::lintpass::{lint_source, Finding};

/// Lint every `.rs` file under `base`, returning `(rel_path, findings)`
/// with paths relative to `base` (so the rules' path scoping sees the
/// same `src/coordinator/…` suffixes as the real tree).
fn lint_subtree(base: &Path) -> Vec<(String, Vec<Finding>)> {
    fn walk(dir: &Path, base: &Path, out: &mut Vec<(String, Vec<Finding>)>) {
        let mut entries: Vec<_> =
            fs::read_dir(dir).expect("fixture dir").map(|e| e.expect("entry").path()).collect();
        entries.sort();
        for path in entries {
            if path.is_dir() {
                walk(&path, base, out);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(base)
                    .expect("under base")
                    .to_string_lossy()
                    .replace('\\', "/");
                let text = fs::read_to_string(&path).expect("fixture source");
                out.push((rel.clone(), lint_source(&rel, &text)));
            }
        }
    }
    let mut out = Vec::new();
    walk(base, base, &mut out);
    out
}

#[test]
fn every_rule_fires_on_bad_and_stays_silent_on_good() {
    let corpus = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("lint_fixtures");
    let mut covered: Vec<String> = Vec::new();

    let mut rule_dirs: Vec<_> = fs::read_dir(&corpus)
        .expect("corpus dir")
        .map(|e| e.expect("entry").path())
        .filter(|p| p.is_dir())
        .collect();
    rule_dirs.sort();
    for dir in rule_dirs {
        let rule = dir.file_name().expect("dir name").to_string_lossy().into_owned();
        assert!(
            rules::is_known_rule(&rule),
            "fixture dir {rule} does not match any rule id"
        );
        covered.push(rule.clone());

        // bad: at least one unsuppressed finding, all of this rule
        let bad = lint_subtree(&dir.join("bad"));
        assert!(!bad.is_empty(), "{rule}/bad is empty");
        let mut fired = 0usize;
        for (rel, findings) in &bad {
            let unsup: Vec<&Finding> = findings.iter().filter(|f| !f.suppressed).collect();
            assert!(!unsup.is_empty(), "{rule}/bad/{rel}: rule did not fire");
            for f in &unsup {
                assert_eq!(
                    f.rule, rule,
                    "{rule}/bad/{rel}:{}: cross-contamination — [{}] {}",
                    f.line, f.rule, f.message
                );
            }
            fired += unsup.len();
        }
        assert!(fired >= 1, "{rule}: nothing fired across bad fixtures");

        // good: the whole pass is silent (suppressed findings allowed —
        // the stale-allow twin demonstrates a live suppression)
        let good = lint_subtree(&dir.join("good"));
        assert!(!good.is_empty(), "{rule}/good is empty");
        for (rel, findings) in &good {
            let unsup: Vec<String> = findings
                .iter()
                .filter(|f| !f.suppressed)
                .map(|f| format!("{}:{}: [{}] {}", rel, f.line, f.rule, f.message))
                .collect();
            assert!(
                unsup.is_empty(),
                "{rule}/good/{rel} must be clean:\n{}",
                unsup.join("\n")
            );
        }
    }

    // the corpus must cover every rule, stale-allow included
    covered.sort_unstable();
    let mut want: Vec<String> = rules::RULES.iter().map(|r| r.to_string()).collect();
    want.sort_unstable();
    assert_eq!(covered, want, "corpus coverage != rule set");
}

/// The stale-allow good twin exercises the suppression machinery: its
/// finding must surface as *suppressed* with the written justification.
#[test]
fn good_twin_suppression_carries_its_reason() {
    let corpus = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("lint_fixtures");
    let good = lint_subtree(&corpus.join("stale-allow").join("good"));
    let sup: Vec<&Finding> =
        good.iter().flat_map(|(_, fs)| fs).filter(|f| f.suppressed).collect();
    assert_eq!(sup.len(), 1, "expected exactly one suppressed finding");
    assert_eq!(sup[0].rule, "no-stray-threads");
    assert!(sup[0].reason.as_deref().unwrap_or("").contains("pool migration"));
}
