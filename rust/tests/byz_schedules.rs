//! Byzantine-schedule property suite (DESIGN.md S16): the seeded
//! adversary plane composed with the robust reputation-gated merge.
//! Pins the breakdown point (⌈m/2⌉−1 corrupt nodes tolerated, ⌈m/2⌉
//! not), NaN rejection at the decode boundary, exact meter↔transcript
//! reconciliation under lossy+Byzantine schedules, bit-identical replay
//! across the in-process and loopback-TCP engines, and the tol-driven
//! early stop of the iterative protocols.

use std::sync::Arc;

use deigen::coordinator::fault::FaultAction;
use deigen::coordinator::{
    run_cluster_faulty, run_cluster_tcp, ClusterConfig, FaultPlan, FaultRunConfig,
    FaultyClusterResult, LinkDir, ProtocolKind, RobustMode, RobustPolicy, WorkerData,
};
use deigen::linalg::subspace::dist2;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;
use deigen::synth::{CovModel, SpectrumModel};
use deigen::testkit::{check, tol};

fn pca_workers(seed: u64, d: usize, r: usize, m: usize, n: usize) -> (Mat, Vec<WorkerData>) {
    let mut rng = Pcg64::seed(seed);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    let workers = (0..m)
        .map(|i| {
            WorkerData::dense(CovModel::empirical_cov(&cov.sample(n, &mut rng.split(i as u64))))
        })
        .collect();
    (cov.principal_subspace(), workers)
}

fn byz_plan(spec: &str, seed: u64) -> FaultPlan {
    FaultPlan::parse(spec).expect("byz spec must parse").seeded(seed)
}

fn run_with(
    m: usize,
    seed: u64,
    protocol: ProtocolKind,
    fc: &FaultRunConfig,
    robust: RobustMode,
) -> (f64, FaultyClusterResult, Mat) {
    let (truth, workers) = pca_workers(seed, 24, 3, m, 200);
    let cfg = ClusterConfig {
        r: 3,
        protocol,
        seed,
        robust: RobustPolicy::with_mode(robust),
        ..Default::default()
    };
    let res = run_cluster_faulty(workers, Arc::new(NativeEngine::default()), &cfg, fc);
    (dist2(&res.estimate, &truth), res, truth)
}

/// The acceptance pin: at m = 8 with ⌈m/2⌉−1 = 3 colluding nodes, the
/// robust screen keeps qpower AND sanger within `tol::STAT` of the clean
/// run, while the plain mean on the very same schedule breaks.
#[test]
fn robust_merge_tolerates_corrupt_minority_where_plain_breaks() {
    let (m, seed) = (8usize, 21u64);
    for protocol in [
        ProtocolKind::parse("qpower", 3, 0.0).unwrap(),
        ProtocolKind::parse("sanger", 3, 0.0).unwrap(),
    ] {
        let name = protocol.name();
        let full = FaultRunConfig::full(m);
        let byz = FaultRunConfig { plan: byz_plan("byz=3:collude", seed), ..FaultRunConfig::full(m) };

        let (clean, _, _) = run_with(m, seed, protocol.clone(), &full, RobustMode::Off);
        let (plain, _, _) = run_with(m, seed, protocol.clone(), &byz, RobustMode::Off);
        let (robust, res, _) = run_with(m, seed, protocol.clone(), &byz, RobustMode::Screen);

        check::assert_orthonormal(&res.estimate, tol::FACTOR, name);
        assert!(robust < tol::STAT, "{name}: robust sin-theta {robust} under 3/8 colluders");
        assert!(
            (robust - clean).abs() < tol::STAT,
            "{name}: robust {robust} drifted from clean {clean}"
        );
        assert!(
            plain > tol::STAT,
            "{name}: plain merge survived 3/8 colluders (sin-theta {plain}) — \
             the attack is too tame to pin anything"
        );
        // the persistent colluders were reputation-quarantined, and the
        // control events landed in the transcript
        let quarantined = res
            .transcript
            .events
            .iter()
            .filter(|e| matches!(e.action, FaultAction::Quarantined))
            .count();
        assert!(quarantined >= 3, "{name}: only {quarantined} quarantine events");
        assert!(res.comm.msgs_ctrl > 0, "{name}: quarantine notices not metered as control");
    }
}

/// The breakdown point is one half: at m = 9, ⌈m/2⌉−1 = 4 colluders are
/// screened out, but ⌈m/2⌉ = 5 capture the robust reference (their mutual
/// Procrustes distance is exactly zero) and the estimate degrades.
#[test]
fn breakdown_point_sits_at_half_the_cluster() {
    let (m, seed) = (9usize, 33u64);
    let protocol = ProtocolKind::parse("qpower", 3, 0.0).unwrap();
    let minority = FaultRunConfig { plan: byz_plan("byz=4:collude", seed), ..FaultRunConfig::full(m) };
    let majority = FaultRunConfig { plan: byz_plan("byz=5:collude", seed), ..FaultRunConfig::full(m) };
    let (d_min, _, _) = run_with(m, seed, protocol.clone(), &minority, RobustMode::Screen);
    let (d_maj, _, _) = run_with(m, seed, protocol, &majority, RobustMode::Screen);
    assert!(d_min < tol::STAT, "4/9 colluders should be screened: sin-theta {d_min}");
    assert!(
        d_maj > tol::STAT,
        "5/9 colluders hold the majority; the robust merge must break (sin-theta {d_maj})"
    );
    assert!(d_maj > 2.0 * d_min, "breakdown curve did not actually break: {d_min} -> {d_maj}");
}

/// A NaN-flooding adversary is rejected at the decode boundary: the
/// rejection is metered (`panels_rejected`), nothing panics, no NaN
/// reaches the merge, and accuracy holds on the honest panels — in plain
/// AND robust mode (the boundary check is mode-independent).
#[test]
fn nan_flood_is_rejected_at_the_boundary_not_propagated() {
    let (m, seed) = (6usize, 47u64);
    let protocol = ProtocolKind::parse("qpower", 3, 0.0).unwrap();
    let fc = FaultRunConfig {
        plan: byz_plan("byz=2:nan", seed),
        quorum: m - 2,
        grace_ms: 0.0,
        straggler_ms: 0.0,
    };
    for mode in [RobustMode::Off, RobustMode::Screen] {
        let (dist, res, _) = run_with(m, seed, protocol.clone(), &fc, mode);
        assert!(res.comm.panels_rejected > 0, "NaN panels must be metered as rejected");
        assert!(res.estimate.as_slice().iter().all(|v| v.is_finite()), "NaN reached the merge");
        check::assert_orthonormal(&res.estimate, tol::FACTOR, "nan-flood estimate");
        assert!(dist < tol::STAT, "honest-only merge should stay accurate: {dist}");
    }
}

/// The meters and the transcript stay in exact agreement when a lossy
/// link schedule and a Byzantine adversary fire together with the robust
/// gate on, for every swept cluster size. Quarantine events are control
/// traffic and must not leak into the payload accounting.
#[test]
fn meters_reconcile_exactly_under_lossy_plus_byz() {
    for &m in &[4usize, 8, 16] {
        let seed = 60 + m as u64;
        let count = (m / 2).saturating_sub(1).max(1);
        let plan = FaultPlan {
            drop_p: 0.15,
            delay_p: 0.3,
            delay_ms: 30.0,
            dup_p: 0.1,
            ..byz_plan(&format!("byz={count}:rotate"), seed)
        };
        let fc = FaultRunConfig { plan, quorum: m - 1, grace_ms: 5.0, straggler_ms: 1000.0 };
        let protocol = ProtocolKind::parse("qpower", 3, 0.0).unwrap();
        let (_, res, _) = run_with(m, seed, protocol, &fc, RobustMode::Screen);
        let up = res.transcript.counts(LinkDir::Up);
        let down = res.transcript.counts(LinkDir::Down);
        assert_eq!(up.msgs, res.comm.msgs_up, "m={m} up msgs");
        assert_eq!(up.bytes, res.comm.bytes_up, "m={m} up bytes");
        assert_eq!(down.msgs, res.comm.msgs_down, "m={m} down msgs");
        assert_eq!(down.bytes, res.comm.bytes_down, "m={m} down bytes");
        assert_eq!(up.retries + down.retries, res.comm.msgs_retry, "m={m} retries");
        assert_eq!(up.dropped + down.dropped, res.comm.msgs_dropped, "m={m} drops");
        assert_eq!(up.dups + down.dups, res.comm.msgs_dup, "m={m} dups");
        assert_eq!(up.timeouts + down.timeouts, res.comm.timeouts, "m={m} timeouts");
    }
}

/// A lossy+Byzantine schedule replays bit-identically: two in-process
/// runs with the same seeds agree on the estimate, every meter, and the
/// transcript (quarantine events included); a different plan seed does
/// not.
#[test]
fn lossy_byz_schedule_replays_bit_identically_in_process() {
    let (m, seed) = (8usize, 71u64);
    let fc = |plan_seed: u64| FaultRunConfig {
        plan: FaultPlan {
            drop_p: 0.1,
            dup_p: 0.1,
            ..byz_plan("byz=3:collude", plan_seed)
        },
        quorum: m - 1,
        grace_ms: 5.0,
        straggler_ms: 500.0,
    };
    let protocol = ProtocolKind::parse("qpower", 3, 0.0).unwrap();
    let (_, a, _) = run_with(m, seed, protocol.clone(), &fc(123), RobustMode::Screen);
    let (_, b, _) = run_with(m, seed, protocol.clone(), &fc(123), RobustMode::Screen);
    assert!(!a.transcript.events.is_empty());
    assert!(
        a.transcript.events.iter().any(|e| matches!(e.action, FaultAction::Quarantined)),
        "schedule produced no quarantine events — nothing Byzantine to replay"
    );
    assert_eq!(a.transcript, b.transcript);
    assert_eq!(a.comm, b.comm);
    assert_eq!(a.per_round, b.per_round);
    assert!(a.estimate.sub(&b.estimate).max_abs() == 0.0, "estimate not bit-identical");
    let (_, c, _) = run_with(m, seed, protocol, &fc(124), RobustMode::Screen);
    assert_ne!(a.transcript, c.transcript, "different plan seeds replayed identically");
}

/// Loopback sockets can be unavailable in sandboxed environments; a bind
/// failure skips the test rather than failing it.
fn sockets_available() -> bool {
    match std::net::TcpListener::bind("127.0.0.1:0") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping TCP byz replay: loopback unavailable ({e})");
            false
        }
    }
}

/// The same lossy+Byzantine schedule replays bit-identically across the
/// loopback-TCP engine and the in-process engine: estimate, per-round
/// meters, and transcript — corruption is a pure hash of
/// (seed, node, round), never of engine timing.
#[test]
fn lossy_byz_schedule_replays_bit_identically_over_tcp() {
    if !sockets_available() {
        return;
    }
    let (m, seed) = (5usize, 83u64);
    let plan = FaultPlan {
        drop_p: 0.15,
        delay_p: 0.3,
        delay_ms: 20.0,
        dup_p: 0.1,
        ..byz_plan("byz=2:rotate", seed)
    };
    let fc = FaultRunConfig { plan, quorum: m - 1, grace_ms: 40.0, straggler_ms: 400.0 };
    let cfg = ClusterConfig {
        r: 3,
        protocol: ProtocolKind::parse("qpower", 3, 0.0).unwrap(),
        seed,
        robust: RobustPolicy::with_mode(RobustMode::Screen),
        ..Default::default()
    };
    let (_, workers) = pca_workers(seed, 24, 3, m, 200);
    let tcp = run_cluster_tcp(workers, Arc::new(NativeEngine::default()), &cfg, &fc)
        .expect("loopback TCP run failed");
    let (_, workers2) = pca_workers(seed, 24, 3, m, 200);
    let local = run_cluster_faulty(workers2, Arc::new(NativeEngine::default()), &cfg, &fc);
    assert!(
        tcp.estimate.sub(&local.estimate).max_abs() == 0.0,
        "TCP vs in-process estimate not bit-identical under lossy+byz"
    );
    assert_eq!(tcp.comm, local.comm, "meters diverge");
    assert_eq!(tcp.per_round, local.per_round, "per-round meters diverge");
    assert_eq!(tcp.transcript, local.transcript, "transcripts diverge");
}

/// `--tol` early stop: a converging iterative run under a positive
/// tolerance stops before its round budget and therefore records strictly
/// fewer per-round meter buckets than the same run with tol = 0.
#[test]
fn tol_early_stop_records_fewer_per_round_buckets() {
    let (m, seed) = (6usize, 91u64);
    for name in ["qpower", "sanger"] {
        let budget = 6usize;
        let full = ProtocolKind::parse(name, budget, 0.0).unwrap();
        let tolled = ProtocolKind::parse(name, budget, 0.2).unwrap();
        let (_, all_rounds, _) =
            run_with(m, seed, full, &FaultRunConfig::full(m), RobustMode::Off);
        let (dist, early, _) =
            run_with(m, seed, tolled, &FaultRunConfig::full(m), RobustMode::Off);
        assert!(
            early.per_round.len() < all_rounds.per_round.len(),
            "{name}: tol run recorded {} buckets, budget run {}",
            early.per_round.len(),
            all_rounds.per_round.len()
        );
        assert!(dist < tol::STAT, "{name}: early-stopped estimate degraded: {dist}");
    }
}
