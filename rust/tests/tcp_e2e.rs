//! Loopback-TCP integration tests (tier-1): end-to-end Algorithm 1 over
//! real sockets — length-prefixed frames, real worker threads — with one
//! injected crash and one delayed straggler, checked against the
//! in-process engine (bit-identical estimate, meters, and transcript)
//! and against the full-participation sin-Θ within `tol::STAT`. Skips
//! gracefully where loopback sockets are unavailable.

use std::sync::Arc;

use deigen::coordinator::{
    run_cluster_faulty, run_cluster_tcp, ClusterConfig, FaultPlan, FaultRunConfig, ProtocolKind,
    Topology, WireCodec, WorkerData,
};
use deigen::linalg::subspace::dist2;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;
use deigen::synth::{CovModel, SpectrumModel};
use deigen::testkit::{check, tol};

fn pca_workers(seed: u64, d: usize, r: usize, m: usize, n: usize) -> (Mat, Vec<WorkerData>) {
    let mut rng = Pcg64::seed(seed);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    let workers = (0..m)
        .map(|i| {
            WorkerData::dense(CovModel::empirical_cov(&cov.sample(n, &mut rng.split(i as u64))))
        })
        .collect();
    (cov.principal_subspace(), workers)
}

/// Loopback sockets can be unavailable in sandboxed environments; a bind
/// failure skips the test rather than failing it.
fn sockets_available() -> bool {
    match std::net::TcpListener::bind("127.0.0.1:0") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping TCP e2e: loopback unavailable ({e})");
            false
        }
    }
}

/// The acceptance scenario: quorum m−1 under one injected crash plus one
/// delayed straggler, over real sockets. The TCP estimate must be
/// bit-identical to the in-process engine under the same plan, and match
/// the full-participation run within `tol::STAT`.
#[test]
fn tcp_e2e_crash_plus_straggler_matches_in_process_and_full_runs() {
    if !sockets_available() {
        return;
    }
    let (m, seed) = (6usize, 17u64);
    // node 3 crashes before round 0; node 2's uploads arrive 600 virtual
    // ms late — inside the straggler window, far outside the grace window
    let plan = FaultPlan::parse("crash=3@0, slow=2:600").unwrap().seeded(seed);
    let fc = FaultRunConfig { plan, quorum: m - 1, grace_ms: 150.0, straggler_ms: 5000.0 };
    let cfg = ClusterConfig { r: 3, seed, ..Default::default() };

    let (truth, workers) = pca_workers(seed, 24, 3, m, 200);
    let tcp = run_cluster_tcp(workers, Arc::new(NativeEngine::default()), &cfg, &fc)
        .expect("loopback TCP run failed");

    // the straggler late-merged, the crashed node is lost
    assert!(tcp.lost.contains(&3), "crashed node not lost: {:?}", tcp.lost);
    assert_eq!(tcp.late_merged, vec![2], "straggler not late-merged");
    assert_eq!(tcp.in_quorum.len(), m - 2);
    check::assert_orthonormal(&tcp.estimate, tol::FACTOR, "tcp estimate");

    // bit-identical to the in-process engine under the identical plan
    let (_, workers2) = pca_workers(seed, 24, 3, m, 200);
    let local = run_cluster_faulty(workers2, Arc::new(NativeEngine::default()), &cfg, &fc);
    assert!(
        tcp.estimate.sub(&local.estimate).max_abs() == 0.0,
        "TCP vs in-process estimate not bit-identical: {}",
        tcp.estimate.sub(&local.estimate).max_abs()
    );
    assert_eq!(tcp.comm, local.comm, "TCP vs in-process meters diverge");
    assert_eq!(tcp.transcript, local.transcript, "TCP vs in-process transcripts diverge");
    assert_eq!(tcp.in_quorum, local.in_quorum);
    assert_eq!(tcp.late_merged, local.late_merged);
    assert_eq!(tcp.lost, local.lost);

    // and within statistical tolerance of full participation
    let (_, workers3) = pca_workers(seed, 24, 3, m, 200);
    let full = run_cluster_faulty(
        workers3,
        Arc::new(NativeEngine::default()),
        &cfg,
        &FaultRunConfig::full(m),
    );
    assert!(dist2(&tcp.estimate, &truth) < tol::STAT);
    assert!(
        dist2(&tcp.estimate, &full.estimate) < tol::STAT,
        "quorum-under-faults vs full participation: {}",
        dist2(&tcp.estimate, &full.estimate)
    );
}

/// Refinement rounds over real sockets stay bit-identical to the
/// in-process engine, lossy codec included (frames carry the quantized
/// payload byte-exactly).
#[test]
fn tcp_refinement_with_lossy_codec_matches_in_process_engine() {
    if !sockets_available() {
        return;
    }
    let (m, seed) = (4usize, 29u64);
    let plan = FaultPlan::parse("drop=0.1, dup=0.1, rto=5").unwrap().seeded(seed);
    let fc = FaultRunConfig { plan, quorum: m, grace_ms: 50.0, straggler_ms: 500.0 };
    let cfg = ClusterConfig {
        r: 2,
        refine_rounds: 2,
        codec: deigen::coordinator::WireCodec::Int8,
        seed,
        ..Default::default()
    };
    let (_, workers) = pca_workers(seed, 16, 2, m, 150);
    let tcp = run_cluster_tcp(workers, Arc::new(NativeEngine::default()), &cfg, &fc)
        .expect("loopback TCP run failed");
    let (_, workers2) = pca_workers(seed, 16, 2, m, 150);
    let local = run_cluster_faulty(workers2, Arc::new(NativeEngine::default()), &cfg, &fc);
    assert!(tcp.estimate.sub(&local.estimate).max_abs() == 0.0);
    assert_eq!(tcp.comm, local.comm);
    assert_eq!(tcp.transcript, local.transcript);
}

/// The iterative protocols replay bit-identically across the two engines
/// under a lossy fault plan: every round's panels ride the negotiated
/// codec, every link passes through the plan's drop/delay/dup schedule,
/// and the per-round meters, transcript, and estimate must all agree —
/// including the per-node (non-broadcast) down-links of the simulated
/// decentralized protocols.
#[test]
fn tcp_multi_round_protocols_replay_bit_identically_under_lossy_plan() {
    if !sockets_available() {
        return;
    }
    let (m, seed) = (5usize, 31u64);
    let combos = [
        (ProtocolKind::QPower { rounds: 3, tol: 0.0 }, WireCodec::Int8),
        (
            ProtocolKind::Sanger { rounds: 3, step: 0.3, topology: Topology::Ring, tol: 0.0 },
            WireCodec::F64,
        ),
        (
            ProtocolKind::DeepCa { rounds: 2, fastmix: 2, topology: Topology::Ring, tol: 0.0 },
            WireCodec::F64,
        ),
    ];
    for (protocol, codec) in combos {
        let plan =
            FaultPlan::parse("drop=0.15, delay=0.3:20, dup=0.1, rto=5").unwrap().seeded(seed);
        let fc = FaultRunConfig { plan, quorum: m - 1, grace_ms: 40.0, straggler_ms: 400.0 };
        let cfg = ClusterConfig {
            r: 2,
            protocol: protocol.clone(),
            codec,
            seed,
            ..Default::default()
        };
        let (_, workers) = pca_workers(seed, 16, 2, m, 150);
        let tcp = run_cluster_tcp(workers, Arc::new(NativeEngine::default()), &cfg, &fc)
            .expect("loopback TCP run failed");
        let (_, workers2) = pca_workers(seed, 16, 2, m, 150);
        let local = run_cluster_faulty(workers2, Arc::new(NativeEngine::default()), &cfg, &fc);
        let name = protocol.name();
        assert!(
            tcp.estimate.sub(&local.estimate).max_abs() == 0.0,
            "{name}: TCP vs in-process estimate not bit-identical"
        );
        assert_eq!(tcp.comm, local.comm, "{name}: meters diverge");
        assert_eq!(tcp.per_round, local.per_round, "{name}: per-round meters diverge");
        assert_eq!(tcp.transcript, local.transcript, "{name}: transcripts diverge");
        check::assert_orthonormal(&tcp.estimate, tol::FACTOR, name);
    }
}
