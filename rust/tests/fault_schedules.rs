//! Deterministic fault-schedule property suite (DESIGN.md S14): for
//! seeded drop/delay/duplicate/partition schedules at m ∈ {4, 8, 16},
//! quorum rounds must recover sin-Θ within `tol::STAT` of the
//! full-participation run, the byte/message meters must reconcile
//! *exactly* with the transcript across retries and duplicates, and
//! replaying the same plan seed must yield bit-identical transcripts.

use std::sync::Arc;

use deigen::coordinator::fault::Partition;
use deigen::coordinator::{
    run_cluster_faulty, ClusterConfig, FaultPlan, FaultRunConfig, FaultyClusterResult,
    LinkDir, WorkerData,
};
use deigen::linalg::subspace::dist2;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;
use deigen::synth::{CovModel, SpectrumModel};
use deigen::testkit::{check, tol};

fn pca_workers(seed: u64, d: usize, r: usize, m: usize, n: usize) -> (Mat, Vec<WorkerData>) {
    let mut rng = Pcg64::seed(seed);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    let workers = (0..m)
        .map(|i| {
            WorkerData::dense(CovModel::empirical_cov(&cov.sample(n, &mut rng.split(i as u64))))
        })
        .collect();
    (cov.principal_subspace(), workers)
}

fn lossy_plan(seed: u64) -> FaultPlan {
    FaultPlan {
        drop_p: 0.15,
        delay_p: 0.3,
        delay_ms: 30.0,
        dup_p: 0.1,
        ..FaultPlan::default()
    }
    .seeded(seed)
}

fn run(m: usize, seed: u64, fc: &FaultRunConfig, refine: usize) -> (f64, FaultyClusterResult) {
    let (truth, workers) = pca_workers(seed, 24, 3, m, 200);
    let cfg = ClusterConfig { r: 3, refine_rounds: refine, seed, ..Default::default() };
    let res = run_cluster_faulty(workers, Arc::new(NativeEngine::default()), &cfg, fc);
    (dist2(&res.estimate, &truth), res)
}

/// Quorum rounds under a lossy schedule stay within `tol::STAT` of full
/// participation, for every swept cluster size.
#[test]
fn quorum_recovers_full_participation_accuracy_at_every_m() {
    for &m in &[4usize, 8, 16] {
        let seed = 40 + m as u64;
        let fc = FaultRunConfig {
            plan: lossy_plan(seed),
            quorum: m - 1,
            grace_ms: 5.0,
            straggler_ms: 1000.0,
        };
        let (dist, res) = run(m, seed, &fc, 0);
        let (full_dist, full) = run(m, seed, &FaultRunConfig::full(m), 0);
        check::assert_orthonormal(&res.estimate, tol::FACTOR, "quorum estimate");
        assert!(dist < tol::STAT, "m={m}: quorum sin-theta {dist}");
        assert!(
            (dist - full_dist).abs() < tol::STAT,
            "m={m}: quorum {dist} vs full {full_dist}"
        );
        assert!(dist2(&res.estimate, &full.estimate) < tol::STAT, "m={m}: estimates diverge");
        // the schedule actually bit: some wire-level fault fired
        let perturbed = res.comm.msgs_retry + res.comm.msgs_dup + res.comm.timeouts;
        assert!(perturbed > 0, "m={m}: schedule too tame to test anything");
    }
}

/// The `CommStats` meters and the transcript are two independent
/// accountings of the same wire events; they must agree *exactly*,
/// including every retransmission, duplicate, and timeout. Snapshot
/// retry/drop/dup/timeout meters are cross-direction totals, so they
/// reconcile against counts(Up) + counts(Down).
#[test]
fn meters_reconcile_exactly_with_the_transcript() {
    for &m in &[4usize, 8, 16] {
        let seed = 80 + m as u64;
        let fc = FaultRunConfig {
            plan: lossy_plan(seed),
            quorum: m - 1,
            grace_ms: 5.0,
            straggler_ms: 1000.0,
        };
        let (_, res) = run(m, seed, &fc, 2);
        let up = res.transcript.counts(LinkDir::Up);
        let down = res.transcript.counts(LinkDir::Down);
        assert_eq!(up.msgs, res.comm.msgs_up, "m={m} up msgs");
        assert_eq!(up.bytes, res.comm.bytes_up, "m={m} up bytes");
        assert_eq!(down.msgs, res.comm.msgs_down, "m={m} down msgs");
        assert_eq!(down.bytes, res.comm.bytes_down, "m={m} down bytes");
        assert_eq!(up.retries + down.retries, res.comm.msgs_retry, "m={m} retries");
        assert_eq!(up.dropped + down.dropped, res.comm.msgs_dropped, "m={m} drops");
        assert_eq!(up.dups + down.dups, res.comm.msgs_dup, "m={m} dups");
        assert_eq!(up.timeouts + down.timeouts, res.comm.timeouts, "m={m} timeouts");
    }
}

/// Replaying the same plan seed yields a bit-identical transcript,
/// meters, and estimate; a different seed yields a different transcript.
#[test]
fn same_seed_replays_bit_identically_different_seed_does_not() {
    let m = 8usize;
    let fc = |plan_seed: u64| FaultRunConfig {
        plan: lossy_plan(plan_seed),
        quorum: m - 1,
        grace_ms: 5.0,
        straggler_ms: 500.0,
    };
    let (_, a) = run(m, 7, &fc(123), 2);
    let (_, b) = run(m, 7, &fc(123), 2);
    assert!(!a.transcript.events.is_empty());
    assert_eq!(a.transcript, b.transcript);
    assert_eq!(a.comm, b.comm);
    assert_eq!(a.in_quorum, b.in_quorum);
    assert_eq!(a.late_merged, b.late_merged);
    assert_eq!(a.lost, b.lost);
    assert!(a.estimate.sub(&b.estimate).max_abs() == 0.0, "estimate not bit-identical");
    let (_, c) = run(m, 7, &fc(124), 2);
    assert_ne!(a.transcript, c.transcript, "different plan seeds produced equal transcripts");
}

/// A leader-side partition blacks out a node range for a window of
/// rounds: their messages time out (metered), the quorum proceeds
/// without them, and accuracy holds.
#[test]
fn partition_window_times_out_but_quorum_proceeds() {
    let m = 8usize;
    let seed = 11u64;
    let plan = FaultPlan {
        partitions: vec![Partition { lo: 1, hi: 2, round: 0, rounds: 1 }],
        ..FaultPlan::default()
    }
    .seeded(seed);
    let fc = FaultRunConfig { plan, quorum: m - 2, grace_ms: 0.0, straggler_ms: 0.0 };
    let (dist, res) = run(m, seed, &fc, 0);
    // nodes 1 and 2 lose every round-0 attempt: one timeout each, every
    // attempt (first send + retries) metered as a drop
    assert_eq!(res.comm.timeouts, 2);
    assert_eq!(res.comm.msgs_dropped, 2 * (deigen::coordinator::fault::DEFAULT_RETRIES + 1));
    assert!(res.lost.contains(&1) && res.lost.contains(&2));
    assert_eq!(res.in_quorum.len(), m - 2);
    assert!(dist < tol::STAT, "partitioned quorum sin-theta {dist}");
}
