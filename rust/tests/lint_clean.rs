//! Tier-1 gate: `deigen-lint` over the real tree must be clean — zero
//! unsuppressed findings and zero stale allows. This is the same pass CI
//! runs through the `deigen_lint` binary; running it as a test makes a
//! plain `cargo test` catch an invariant regression without the binary.

use deigen::lintpass;

#[test]
fn real_tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lintpass::lint_tree(root).expect("walking the workspace");

    // the walker must actually have seen the tree, not an empty dir —
    // the crate has well over 80 source files
    assert!(
        report.files_scanned > 80,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );

    let bad: Vec<String> = report
        .unsuppressed()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        bad.is_empty(),
        "deigen-lint found {} unsuppressed finding(s):\n{}",
        bad.len(),
        bad.join("\n")
    );
}

/// Every suppression in the real tree must carry a justification the
/// audit accepted (the scanner rejects reason-less allows as malformed,
/// so this documents the contract end-to-end).
#[test]
fn every_real_tree_suppression_is_justified() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lintpass::lint_tree(root).expect("walking the workspace");
    for f in report.findings.iter().filter(|f| f.suppressed) {
        let reason = f.reason.as_deref().unwrap_or("");
        assert!(
            reason.len() >= 10,
            "{}:{}: suppression of {} has a trivial reason: {reason:?}",
            f.file,
            f.line,
            f.rule
        );
    }
}
