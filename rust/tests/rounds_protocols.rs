//! Round-protocol integration suite (tier-1, DESIGN.md S15):
//!
//! 1. The `RoundProtocol` one-shot instance is bit-identical to a
//!    spec-level oracle of the pre-engine pipeline (Algorithm 1 +
//!    Algorithm-2 refinement) across seeds, codecs, refinement depths,
//!    and both transports — the engine refactor changed nothing the
//!    wire can see.
//! 2. The rounds-vs-bytes frontier claim: in the calibrated regime
//!    (d=64, r=5, m=32), three quantized power rounds move fewer bytes
//!    than one f64 one-shot upload and land a strictly better estimate.
//! 3. Per-round meters reconcile field-wise with the run totals on a
//!    real multi-round cluster run under a lossy fault plan.

use std::sync::Arc;

use deigen::align::{mean_qr, procrustes_fix_with_reference};
use deigen::coordinator::{
    run_cluster_faulty, run_cluster_tcp, ClusterConfig, CommSnapshot, FaultPlan,
    FaultRunConfig, ProtocolKind, Shard, WireCodec, WorkerData,
};
use deigen::linalg::gemm::matmul;
use deigen::linalg::procrustes::procrustes_align;
use deigen::linalg::subspace::dist2;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::{LocalSolver, NativeEngine};
use deigen::testkit::tol;

/// m dense noisy observations of a spectrum-{1.0, 0.3} symmetric ground
/// truth — the same generator the coordinator unit tests and the
/// `exp rounds` sweep use.
fn noisy_observations(
    rng: &mut Pcg64,
    d: usize,
    r: usize,
    m: usize,
    noise: f64,
) -> (Mat, Vec<Mat>) {
    let q = rng.haar_orthogonal(d);
    let evs: Vec<f64> = (0..d).map(|i| if i < r { 1.0 } else { 0.3 }).collect();
    let x = matmul(&Mat::from_fn(d, d, |i, j| q[(i, j)] * evs[j]), &q.transpose());
    let obs = (0..m)
        .map(|_| {
            let mut e = rng.normal_mat(d, d).scale(noise);
            e.symmetrize();
            x.add(&e)
        })
        .collect();
    (q.col_block(0, r), obs)
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

/// Spec-level oracle for the pre-engine one-shot pipeline under full
/// participation: round-0 local solves on per-worker rng streams, codec
/// encode/decode at every boundary, leader-side Procrustes aggregation,
/// then `refine` broadcast-align-average rounds seeded from node 0's
/// decoded panel. Mirrors the legacy `run_cluster` operation-for-
/// operation, so the engine must reproduce it bit-for-bit.
fn oneshot_oracle(obs: &[Mat], r: usize, seed: u64, codec: WireCodec, refine: usize) -> Mat {
    let solver = NativeEngine::default();
    let mut exact = Vec::with_capacity(obs.len());
    let mut decoded = Vec::with_capacity(obs.len());
    for (i, o) in obs.iter().enumerate() {
        let shard = Shard::Dense(o.clone());
        let mut rng = Pcg64::seed_stream(seed, i as u64 + 1);
        let panel = solver.leading_subspace_op(&shard, r, &mut rng);
        decoded.push(codec.encode(&panel).decode());
        exact.push(panel);
    }
    let mut reference = if refine == 0 {
        procrustes_fix_with_reference(&decoded, &decoded[0])
    } else {
        decoded[0].clone()
    };
    for _ in 1..=refine {
        // the broadcast is encoded once and every worker sees its decode
        let ref_dec = codec.encode(&reference).decode();
        let mut replies: Vec<Mat> = exact
            .iter()
            .map(|p| codec.encode(&procrustes_align(p, &ref_dec)).decode())
            .collect();
        // span-only codecs decode to an arbitrary basis; the leader
        // re-anchors to its own (un-encoded) reference before averaging
        if !codec.preserves_representative() {
            for p in replies.iter_mut() {
                *p = procrustes_align(p, &reference);
            }
        }
        reference = mean_qr(&replies);
    }
    reference
}

/// Satellite 4: the engine's `ProtocolKind::OneShot` path is
/// bit-identical to the pre-refactor pipeline — across seeds, codecs,
/// refinement depths, and (for one seed) the loopback-TCP engine.
#[test]
fn oneshot_round_engine_is_bit_identical_to_the_legacy_pipeline() {
    let (d, r, m) = (16usize, 2usize, 5usize);
    for seed in [1u64, 5] {
        for codec in [WireCodec::F64, WireCodec::Int8, WireCodec::FdSketch { l: 2 }] {
            for refine in [0usize, 2] {
                let mut rng = Pcg64::seed(seed);
                let (_, obs) = noisy_observations(&mut rng, d, r, m, 0.05);
                let want = oneshot_oracle(&obs, r, seed, codec, refine);
                let workers: Vec<WorkerData> =
                    obs.iter().map(|o| WorkerData::dense(o.clone())).collect();
                let cfg = ClusterConfig {
                    r,
                    refine_rounds: refine,
                    protocol: ProtocolKind::OneShot,
                    codec,
                    seed,
                    ..Default::default()
                };
                let res = run_cluster_faulty(
                    workers,
                    Arc::new(NativeEngine::default()),
                    &cfg,
                    &FaultRunConfig::full(m),
                );
                assert!(
                    res.estimate.sub(&want).max_abs() == 0.0,
                    "engine vs legacy oracle diverge (seed={seed} codec={} refine={refine}): {}",
                    codec.name(),
                    res.estimate.sub(&want).max_abs()
                );
                // and the engine itself replays bit-identically
                let workers2: Vec<WorkerData> =
                    obs.iter().map(|o| WorkerData::dense(o.clone())).collect();
                let res2 = run_cluster_faulty(
                    workers2,
                    Arc::new(NativeEngine::default()),
                    &cfg,
                    &FaultRunConfig::full(m),
                );
                assert!(res.estimate.sub(&res2.estimate).max_abs() == 0.0);
                assert_eq!(res.comm, res2.comm);
                assert_eq!(res.transcript, res2.transcript);

                // the TCP engine lands on the very same bits (one seed
                // keeps the socket churn bounded; tcp_e2e covers faults)
                if seed == 1 {
                    let Ok(listener) = std::net::TcpListener::bind("127.0.0.1:0") else {
                        eprintln!("skipping TCP leg: loopback unavailable");
                        continue;
                    };
                    drop(listener);
                    let workers3: Vec<WorkerData> =
                        obs.iter().map(|o| WorkerData::dense(o.clone())).collect();
                    let tcp = run_cluster_tcp(
                        workers3,
                        Arc::new(NativeEngine::default()),
                        &cfg,
                        &FaultRunConfig::full(m),
                    )
                    .expect("loopback TCP run failed");
                    assert!(
                        tcp.estimate.sub(&want).max_abs() == 0.0,
                        "TCP engine vs legacy oracle diverge (codec={} refine={refine})",
                        codec.name()
                    );
                    assert_eq!(tcp.comm, res.comm);
                    assert_eq!(tcp.transcript, res.transcript);
                }
            }
        }
    }
}

/// The acceptance claim behind `deigen exp rounds`: a regime where an
/// iterative protocol beats one-shot at equal byte budget. At (d=64,
/// r=5) an int8 panel message is ~1/8 of an f64 one, so K=3 quantized
/// power rounds (1 upload + 3 down/up exchanges, all int8) fit inside
/// the single f64 one-shot upload budget — and the power iterations
/// contract the estimate error below the one-shot baseline.
#[test]
fn qpower_int8_beats_oneshot_f64_at_equal_byte_budget() {
    let (d, r, m, noise) = (64usize, 5usize, 32usize, 0.08);
    let trials = 5;
    let mut margins = Vec::with_capacity(trials);
    let mut qpower_errs = Vec::with_capacity(trials);
    for trial in 0..trials {
        let mut rng = Pcg64::seed_stream(4242, 100 + trial as u64);
        let (truth, obs) = noisy_observations(&mut rng, d, r, m, noise);
        let mk = || -> Vec<WorkerData> {
            obs.iter().map(|o| WorkerData::dense(o.clone())).collect()
        };
        let base_cfg = ClusterConfig { r, seed: 4242, ..Default::default() };
        let oneshot = run_cluster_faulty(
            mk(),
            Arc::new(NativeEngine::default()),
            &base_cfg,
            &FaultRunConfig::full(m),
        );
        let q_cfg = ClusterConfig {
            r,
            protocol: ProtocolKind::QPower { rounds: 3, tol: 0.0 },
            codec: WireCodec::Int8,
            seed: 4242,
            ..Default::default()
        };
        let qpower = run_cluster_faulty(
            mk(),
            Arc::new(NativeEngine::default()),
            &q_cfg,
            &FaultRunConfig::full(m),
        );
        // the byte budget: total payload (up + down) of the iterative
        // run must not exceed the one-shot f64 upload
        let oneshot_bytes = oneshot.comm.bytes_up + oneshot.comm.bytes_down;
        let qpower_bytes = qpower.comm.bytes_up + qpower.comm.bytes_down;
        assert!(
            qpower_bytes <= oneshot_bytes,
            "trial {trial}: qpower spent {qpower_bytes} B > oneshot {oneshot_bytes} B"
        );
        let err_o = dist2(&oneshot.estimate, &truth);
        let err_q = dist2(&qpower.estimate, &truth);
        margins.push(err_o - err_q);
        qpower_errs.push(err_q);
    }
    let med_margin = median(&mut margins);
    assert!(
        med_margin > 0.0,
        "qpower-int8 did not beat oneshot-f64 at equal bytes: median margin {med_margin}"
    );
    assert!(
        median(&mut qpower_errs) < tol::STAT,
        "qpower estimate not within statistical tolerance of the truth"
    );
}

/// Per-round meters on a real multi-round run under a lossy plan sum
/// field-wise to the run totals: payload, retry/drop/dup, stall — with
/// control traffic round-less by design (appears only in the totals).
#[test]
fn per_round_meters_reconcile_with_run_totals() {
    let (d, r, m, seed) = (16usize, 2usize, 6usize, 23u64);
    let mut rng = Pcg64::seed(seed);
    let (_, obs) = noisy_observations(&mut rng, d, r, m, 0.05);
    let workers: Vec<WorkerData> = obs.iter().map(|o| WorkerData::dense(o.clone())).collect();
    let plan = FaultPlan::parse("drop=0.1, delay=0.2:10, dup=0.1, rto=5").unwrap().seeded(seed);
    let fc = FaultRunConfig { plan, quorum: m - 1, grace_ms: 20.0, straggler_ms: 200.0 };
    let cfg = ClusterConfig {
        r,
        protocol: ProtocolKind::QPower { rounds: 3, tol: 0.0 },
        codec: WireCodec::Int8,
        seed,
        ..Default::default()
    };
    let res = run_cluster_faulty(workers, Arc::new(NativeEngine::default()), &cfg, &fc);
    // 1 collect round + 3 protocol rounds, one snapshot each
    assert_eq!(res.comm.rounds, 4);
    assert_eq!(res.per_round.len(), 4);
    let mut acc = CommSnapshot::zero();
    for s in &res.per_round {
        assert_eq!((s.bytes_ctrl, s.msgs_ctrl), (0, 0), "control traffic is round-less");
        acc.accumulate(s);
    }
    assert_eq!(
        acc,
        CommSnapshot { bytes_ctrl: 0, msgs_ctrl: 0, ..res.comm },
        "per-round snapshots do not sum to the run totals"
    );
    assert!(res.comm.bytes_ctrl > 0, "Done control traffic missing from totals");
    // round 0 carries no down-link payload; every protocol round does
    assert_eq!(res.per_round[0].bytes_down, 0);
    for (k, s) in res.per_round.iter().enumerate().skip(1) {
        assert!(s.bytes_down > 0, "round {k} sent no down-link payload");
    }
}
