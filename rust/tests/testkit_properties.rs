//! Testkit-backed property tests: the production kernels and the full
//! single-round protocol pinned against the independent oracles, over
//! seeded instance families. Everything here is deterministic — fixed
//! seeds, no wall-clock, and results independent of thread count (the
//! threaded kernels partition work so per-element summation order is
//! identical to the serial path).

use std::sync::Arc;

use deigen::align;
use deigen::coordinator::{run_cluster, ClusterConfig, WorkerData};
use deigen::linalg::gemm::{matmul, syrk_scaled};
use deigen::linalg::qr::thin_qr;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;
use deigen::testkit::{check, gen, oracle, tol};

// ---------------------------------------------------------------------
// kernel properties over seeded families
// ---------------------------------------------------------------------

/// Blocked/threaded GEMM vs the textbook oracle over the adversarial
/// shape sweep, for several seeds (the unit tests run one seed; this is
/// the wider net).
#[test]
fn gemm_oracle_agreement_over_seeds() {
    for seed in 0..3u64 {
        let mut rng = Pcg64::seed(1000 + seed);
        for &(m, k, n) in &gen::gemm_shapes() {
            let a = Mat::from_fn(m, k, |_, _| rng.next_f64() * 2.0 - 1.0);
            let b = Mat::from_fn(k, n, |_, _| rng.next_f64() * 2.0 - 1.0);
            check::assert_close(
                &matmul(&a, &b),
                &oracle::matmul(&a, &b),
                tol::dim_scaled(tol::KERNEL, k),
                &format!("seed {seed} matmul ({m},{k},{n})"),
            );
        }
    }
}

/// Covariance formation (the SYRK hot path) against the oracle Gram at
/// statistically-shaped sizes, including one above the threading cutoff.
#[test]
fn syrk_oracle_agreement_over_seeds() {
    for seed in 0..3u64 {
        let mut rng = Pcg64::seed(2000 + seed);
        for &(n, d) in &[(40usize, 12usize), (300, 90)] {
            let x = rng.normal_mat(n, d);
            check::assert_close(
                &syrk_scaled(&x, n as f64),
                &oracle::gram_scaled(&x, n as f64),
                tol::dim_scaled(tol::KERNEL, n),
                &format!("seed {seed} syrk ({n},{d})"),
            );
        }
    }
}

/// QR factors certified orthonormal + reconstructing through the oracle.
#[test]
fn qr_properties_over_seeds() {
    for seed in 0..4u64 {
        let mut rng = Pcg64::seed(3000 + seed);
        let (m, n) = (20 + 7 * seed as usize, 3 + seed as usize);
        let a = rng.normal_mat(m, n);
        let (q, r) = thin_qr(&a);
        check::assert_orthonormal(&q, tol::FACTOR, &format!("seed {seed} Q"));
        check::assert_close(
            &oracle::matmul(&q, &r),
            &a,
            tol::dim_scaled(tol::FACTOR, m),
            &format!("seed {seed} QR reconstruction"),
        );
        // R upper triangular
        for i in 0..n {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0, "seed {seed}: R not triangular");
            }
        }
    }
}

/// The production eigensolver vs the Jacobi oracle on spiked instances:
/// spectrum agreement and leading-subspace agreement.
#[test]
fn eigensolver_vs_jacobi_oracle_on_spiked_instances() {
    for seed in 0..3u64 {
        let cov = gen::spiked_covariance(20, 3, 1.0, 0.4, 4000 + seed);
        let sigma = cov.sigma();
        let (vals, _) = deigen::linalg::eig::sym_eig(&sigma);
        let (ovals, _) = oracle::jacobi_eig(&sigma);
        for (g, o) in vals.iter().zip(&ovals) {
            assert!((g - o).abs() < tol::ITER, "seed {seed}: {g} vs {o}");
        }
        let top = deigen::linalg::eig::top_eigvecs(&sigma, 3).0;
        // the planted basis IS the eigenbasis — both solvers must find it
        assert!(
            check::sin_theta(&top, &cov.truth()) < tol::ITER,
            "seed {seed}: planted subspace missed"
        );
    }
}

/// Adversarial-spectrum eigensolver suite: clustered eigenvalues, exactly
/// repeated eigenvalues, tiny `lambda_r / lambda_{r+1}` gaps,
/// rank-deficient PSD Grams, extreme decay and indefinite mirrors — both
/// the full blocked solver and the dedicated top-r path pinned to the
/// independent cyclic-Jacobi oracle.
#[test]
fn eigensolver_adversarial_spectra_vs_jacobi_oracle() {
    use deigen::linalg::eig::{sym_eig, sym_eig_top_r};
    let (d, r) = (48usize, 4usize);
    for (name, evs) in gen::adversarial_spectra(d, r) {
        let q = gen::haar_orthogonal(d, 0x5bec + name.len() as u64);
        let scaled = Mat::from_fn(d, d, |i, j| q[(i, j)] * evs[j]);
        let a = matmul(&scaled, &q.transpose());
        let (vals, vecs) = sym_eig(&a);
        let (ovals, _) = oracle::jacobi_eig(&a);
        let scale = ovals.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (g, o) in vals.iter().zip(&ovals) {
            assert!(
                (g - o).abs() < tol::ITER * scale,
                "{name}: eigenvalue {g} vs oracle {o}"
            );
        }
        check::assert_orthonormal(&vecs, tol::FACTOR, &format!("{name}: full basis"));
        let (v, lam) = sym_eig_top_r(&a, r);
        check::assert_orthonormal(&v, tol::FACTOR, &format!("{name}: top-r panel"));
        for (j, &l) in lam.iter().enumerate() {
            assert!(
                (l - ovals[d - 1 - j]).abs() < tol::ITER * scale,
                "{name}: top eigenvalue {j}: {l} vs {}",
                ovals[d - 1 - j]
            );
        }
        // residual certificate A V = V diag(lam) — basis-independent, so
        // it holds even where a cluster makes individual vectors arbitrary
        let av = matmul(&a, &v);
        let vl = Mat::from_fn(d, r, |i, j| v[(i, j)] * lam[j]);
        assert!(
            av.sub(&vl).max_abs() < 100.0 * tol::ITER * scale.max(1.0),
            "{name}: top-r residual {:.2e}",
            av.sub(&vl).max_abs()
        );
        // where the spectrum has a clean gap at r, the top-r panel must
        // span the oracle's leading subspace
        let mut sorted = evs.clone();
        sorted.sort_by(|a, b| b.total_cmp(a));
        if sorted[r - 1] - sorted[r] > 1e-3 * scale {
            let otop = oracle::top_eigvecs(&a, r).0;
            assert!(
                check::sin_theta(&v, &otop) < 10.0 * tol::ITER,
                "{name}: leading subspace disagrees with oracle"
            );
        }
    }
}

/// Acceptance gate for the blocked backend: at a dimension where the
/// trailing matvec and the rank-2b GEMMs actually fan out over the pool,
/// a forced single-thread run must be bit-identical to a multi-thread
/// run, for both the full solver and the top-r path.
#[test]
fn eigensolver_thread_plans_bit_identical_at_pooled_sizes() {
    use deigen::linalg::eig::{sym_eig, sym_eig_top_r};
    use deigen::linalg::pool;
    let d = 384; // rows^2 and n2^2 * nb both clear the parallel thresholds
    let mut rng = Pcg64::seed(0xb17_5eed);
    let mut a = rng.normal_mat(d, d);
    a.symmetrize();
    let (vals1, vecs1) = pool::with_threads(1, || sym_eig(&a));
    let (vals4, vecs4) = pool::with_threads(4, || sym_eig(&a));
    assert_eq!(vals1, vals4, "eigenvalues differ across thread plans");
    assert_eq!(
        vecs1.as_slice(),
        vecs4.as_slice(),
        "eigenvectors differ across thread plans"
    );
    let (v1, lam1) = pool::with_threads(1, || sym_eig_top_r(&a, 16));
    let (v4, lam4) = pool::with_threads(4, || sym_eig_top_r(&a, 16));
    assert_eq!(lam1, lam4, "top-r eigenvalues differ across thread plans");
    assert_eq!(v1.as_slice(), v4.as_slice(), "top-r panel differs across thread plans");
}

/// Procrustes rotations: production route == oracle route, and both pass
/// the polar-factor optimality certificate, across noise levels.
#[test]
fn procrustes_certificate_property() {
    for (i, &noise) in [0.01f64, 0.05, 0.2, 0.5].iter().enumerate() {
        let truth = gen::haar_panel(30, 4, 5000 + i as u64);
        let pair = gen::noisy_copies(&truth, 2, noise, 6000 + i as u64);
        let (v, vref) = (&pair[0], &pair[1]);
        let z = deigen::linalg::procrustes::procrustes_rotation(v, vref);
        let cert = check::procrustes_certificate(v, vref, &z);
        assert!(cert < tol::ITER, "noise {noise}: certificate {cert:.2e}");
        check::assert_close(
            &z,
            &oracle::procrustes_rotation(v, vref),
            tol::ITER,
            &format!("noise {noise}: rotation vs oracle"),
        );
    }
}

// ---------------------------------------------------------------------
// end-to-end: Algorithm 1 vs the centralized estimator (Theorem 1)
// ---------------------------------------------------------------------

/// Single-round Algorithm 1 on a spiked-covariance cluster must match the
/// centralized estimator's sin-Θ error up to the paper's Theorem-1-style
/// constant: with per-node perturbations `E_i = X̂ᵢ - Σ`,
///
/// `dist(Alg1, V₁) <= C * (dist(central, V₁) + max_i ||E_i||₂² / gap²)`.
///
/// Every quantity on both sides is computed through testkit oracles
/// (definition-level sin-Θ, Jacobi spectral norms), so the production
/// pipeline cannot grade its own homework.
#[test]
fn algorithm1_matches_centralized_rate_on_spiked_cluster() {
    let (d, r, m, n) = (40usize, 3usize, 10usize, 500usize);
    let cov = gen::spiked_covariance(d, r, 1.0, 0.5, 777);
    let truth = cov.truth();
    let gap = cov.gap();
    let sigma = cov.sigma();

    // per-node empirical covariances from independent sample streams
    let mut rng = Pcg64::seed(778);
    let observations: Vec<Mat> = (0..m)
        .map(|i| {
            let x = cov.sample(n, &mut rng.split(i as u64 + 1));
            syrk_scaled(&x, n as f64)
        })
        .collect();

    // centralized estimator: top-r eigenspace of the pooled covariance
    let mut pooled = Mat::zeros(d, d);
    for c in &observations {
        pooled.axpy(1.0 / m as f64, c);
    }
    let central = deigen::linalg::eig::top_eigvecs(&pooled, r).0;
    let err_central = check::sin_theta(&central, &truth);

    // the distributed protocol, end to end through the threaded cluster
    let workers: Vec<WorkerData> =
        observations.iter().map(|c| WorkerData::dense(c.clone())).collect();
    let cfg = ClusterConfig { r, seed: 779, ..Default::default() };
    let res = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
    check::assert_orthonormal(&res.estimate, tol::FACTOR, "Alg1 estimate");
    let err_alg1 = check::sin_theta(&res.estimate, &truth);

    // single-round protocol shape: m uploads, one round
    assert_eq!(res.comm.rounds, 1);
    assert_eq!(res.comm.msgs_up, m);

    // Theorem-1 constant: quadratic bias from the worst local perturbation
    let max_e = observations
        .iter()
        .map(|c| oracle::spectral_norm(&c.sub(&sigma)))
        .fold(0.0f64, f64::max);
    let bias = (max_e / gap) * (max_e / gap);
    let bound = 8.0 * (err_central + bias);
    assert!(
        err_alg1 <= bound,
        "Alg1 err {err_alg1:.4} exceeds Theorem-1 budget {bound:.4} \
         (central {err_central:.4}, max ||E||={max_e:.4}, gap={gap:.2})"
    );
    // and the distributed estimate is genuinely good, not vacuously bounded
    assert!(err_alg1 < tol::STAT, "Alg1 err {err_alg1:.4} not small");

    // sanity: the cluster's own panels re-aggregated by the library
    // estimator give the identical answer (protocol == library semantics)
    let lib = align::procrustes_fix(&res.local_panels);
    check::assert_close(&res.estimate, &lib, tol::ITER, "cluster vs library Alg1");
}

/// Naive averaging on the same cluster panels (rotated by adversarial but
/// valid per-node rotations) stalls, while Procrustes fixing does not —
/// the failure mode that motivates the paper, verified with oracle
/// metrics.
#[test]
fn naive_average_stalls_under_rotation_ambiguity_oracle_checked() {
    let truth = gen::haar_panel(30, 3, 888);
    let locals = gen::noisy_copies(&truth, 16, 0.05, 889);
    let aligned = align::procrustes_fix(&locals);
    let naive = align::naive_average(&locals);
    let d_aligned = check::sin_theta(&aligned, &truth);
    let d_naive = check::sin_theta(&naive, &truth);
    assert!(d_aligned < 0.12, "aligned {d_aligned:.3}");
    assert!(
        d_naive > 3.0 * d_aligned,
        "naive {d_naive:.3} should be far worse than aligned {d_aligned:.3}"
    );
}

// ---------------------------------------------------------------------
// operator data plane: every SymOp pinned to its dense materialization
// ---------------------------------------------------------------------

/// Every matrix-free operator applied to a random panel must equal the
/// explicit `Mat` product of its dense materialization, over adversarial
/// shapes (degenerate n=1/d=1, tall, wide, and a size whose apply-GEMM
/// crosses the parallel threshold).
#[test]
fn symop_impls_match_dense_materialization_over_adversarial_shapes() {
    use deigen::linalg::symop::{GramOp, GramStackOp, StackedProjectorOp, SymOp, TruncatedSensingOp};
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 2, 2),
        (17, 5, 3),
        (7, 33, 4),
        (160, 96, 8),   // apply GEMM = 160*96*8 crosses DIRECT, syrk big
        (700, 64, 48),  // n*d*r ≈ 2.1M madds: straddles PAR_THRESHOLD
    ];
    for (si, &(n, d, r)) in shapes.iter().enumerate() {
        let mut rng = Pcg64::seed(0x0b5 + si as u64);
        let x = rng.normal_mat(n, d);
        let v = rng.normal_mat(d, r);
        let tol_here = tol::dim_scaled(tol::KERNEL, n.max(d));

        // GramOp vs X^T X / n
        let dense = syrk_scaled(&x, n as f64);
        check::assert_close(
            &GramOp::new(&x).apply(&v),
            &matmul(&dense, &v),
            tol_here,
            &format!("GramOp ({n},{d},{r})"),
        );

        // GramStackOp vs the pooled covariance of 3 shards
        let shards: Vec<Mat> = (0..3).map(|_| rng.normal_mat(n, d)).collect();
        let mut pooled = Mat::zeros(d, d);
        for s in &shards {
            pooled.axpy(1.0 / 3.0, &syrk_scaled(s, n as f64));
        }
        check::assert_close(
            &GramStackOp::new(&shards, (3 * n) as f64).apply(&v),
            &matmul(&pooled, &v),
            tol_here,
            &format!("GramStackOp ({n},{d},{r})"),
        );

        // TruncatedSensingOp vs the dense spectral matrix (with an
        // outlier above the truncation threshold and a negative y)
        let mut y: Vec<f64> = (0..n).map(|_| 0.5 + rng.next_f64()).collect();
        if n > 2 {
            y[0] = 1e6;
            y[1] = -2.0;
        }
        let dn = deigen::sensing::spectral_matrix(&x, &y);
        check::assert_close(
            &TruncatedSensingOp::new(&x, &y).apply(&v),
            &matmul(&dn, &v),
            tol_here,
            &format!("TruncatedSensingOp ({n},{d},{r})"),
        );

        // StackedProjectorOp vs the accumulated mean projector
        let panels: Vec<Mat> = (0..4).map(|_| rng.haar_stiefel(d, r.min(d))).collect();
        let mut proj = Mat::zeros(d, d);
        for w in &panels {
            proj.axpy(1.0 / 4.0, &deigen::linalg::gemm::a_bt(w, w));
        }
        check::assert_close(
            &StackedProjectorOp::new(&panels).apply(&v),
            &matmul(&proj, &v),
            tol_here,
            &format!("StackedProjectorOp ({n},{d},{r})"),
        );
    }
}

/// KatzOp (sparse Horner) vs the dense truncated power series, including
/// a bipartite graph whose spectrum is symmetric around zero.
#[test]
fn katz_op_matches_dense_series_on_adversarial_graphs() {
    use deigen::linalg::symop::{KatzOp, SymOp};
    let mut rng = Pcg64::seed(0xa72);
    let mut graphs = vec![
        deigen::graph::sbm(40, 2, 0.3, 0.05, &mut rng),
        deigen::graph::sbm(25, 1, 0.15, 0.15, &mut rng),
    ];
    // complete bipartite block: adversarially indefinite Katz spectrum
    let mut edges = Vec::new();
    for u in 0..6usize {
        for v in 0..6usize {
            edges.push((u, 6 + v));
        }
    }
    graphs.push(deigen::graph::Graph {
        n: 12,
        edges,
        labels: (0..12).map(|i| usize::from(i >= 6)).collect(),
    });
    for (gi, g) in graphs.iter().enumerate() {
        let dense = deigen::graph::katz_proximity(g, 0.04, 16);
        let v = rng.normal_mat(g.n, 5);
        let got = KatzOp::new(g.n, &g.edges, 0.04, 16).apply(&v);
        check::assert_close(
            &got,
            &matmul(&dense, &v),
            tol::dim_scaled(tol::KERNEL, g.n),
            &format!("KatzOp graph {gi} (n={})", g.n),
        );
    }
}

/// `orth_iter` over a Gram operator agrees with `orth_iter` over the
/// materialized dense plane: the operators share a spectrum, so from the
/// same start panel both land on the same leading subspace with matching
/// Ritz values.
#[test]
fn orth_iter_gram_vs_dense_plane_agreement() {
    use deigen::linalg::orthiter::orth_iter;
    use deigen::linalg::symop::{DenseSymOp, GramOp};
    for seed in 0..3u64 {
        let mut rng = Pcg64::seed(0x09a3 + seed);
        let (n, d, r) = (250usize, 28usize, 3usize);
        let x = rng.normal_mat(n, d);
        let c = syrk_scaled(&x, n as f64);
        let v0 = rng.normal_mat(d, r);
        let (vg, rg) = orth_iter(&GramOp::new(&x), &v0, 150);
        let (vd, rd) = orth_iter(&DenseSymOp::new(&c), &v0, 150);
        let gap = check::sin_theta(&vg, &vd);
        assert!(gap < tol::ITER, "seed {seed}: subspace gap {gap:.2e}");
        for (a, b) in rg.iter().zip(&rd) {
            assert!((a - b).abs() < tol::ITER, "seed {seed}: ritz {a} vs {b}");
        }
        // and both live in the oracle's leading subspace
        let otop = oracle::top_eigvecs(&c, r).0;
        assert!(check::sin_theta(&vg, &otop) < 10.0 * tol::ITER, "seed {seed}: oracle gap");
    }
}

/// Determinism: the same seeds produce bit-identical estimates across two
/// full runs (threaded protocol included).
#[test]
fn end_to_end_deterministic_across_runs() {
    let run = || {
        let cov = gen::spiked_covariance(24, 2, 1.0, 0.5, 999);
        let mut rng = Pcg64::seed(1000);
        let workers: Vec<WorkerData> = (0..6)
            .map(|i| {
                let x = cov.sample(120, &mut rng.split(i as u64));
                WorkerData::dense(syrk_scaled(&x, 120.0))
            })
            .collect();
        let cfg = ClusterConfig { r: 2, seed: 1001, ..Default::default() };
        run_cluster(workers, Arc::new(NativeEngine::default()), &cfg).estimate
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.as_slice(),
        b.as_slice(),
        "cluster runs must be bit-identical for fixed seeds"
    );
}
