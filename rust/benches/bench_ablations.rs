//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. **Federated single round vs decentralized gossip** (§1.2's third
//!    distributed flavor): accuracy and communication of Algorithm 1's one
//!    round vs ring/complete gossip until mixed.
//! 2. **Panel compression**: f64 vs f16 vs int8 uploads — accuracy cost of
//!    shrinking the paper's already-small (d, r) messages.
//! 3. **Frequent Directions** ([25]): shipping mergeable sketches instead
//!    of eigenbasis panels — the related-work alternative pipeline.
//! 4. **Local solver choice**: orthogonal iteration vs shift-and-invert
//!    ([23]) at small eigengaps.
//!
//! Run: `cargo bench --bench bench_ablations`

use deigen::align;
use deigen::benchutil::{bench, fmt_time, header, quick_mode};
use deigen::coordinator::gossip::{gossip_align, spread, Topology};
use deigen::coordinator::WireCodec;
use deigen::linalg::subspace::dist2;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::{LocalSolver, NativeEngine, ShiftInvertEngine};
use deigen::sketch::{dequantize_panel, quantize_panel, Codec, FrequentDirections};
use deigen::synth::{CovModel, SpectrumModel};

fn main() {
    header("design ablations");
    let mut rng = Pcg64::seed(11);
    let (d, r, m, n) = (64usize, 4usize, 16usize, 400usize);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    let truth = cov.principal_subspace();
    let solver = NativeEngine::default();

    // shared local data + panels
    let samples: Vec<Mat> = (0..m).map(|i| cov.sample(n, &mut rng.split(i as u64))).collect();
    let panels: Vec<Mat> = samples
        .iter()
        .enumerate()
        .map(|(i, x)| {
            let mut node_rng = rng.split(1000 + i as u64);
            solver.leading_subspace(&CovModel::empirical_cov(x), r, &mut node_rng)
        })
        .collect();
    let panel_bytes = 8 * d * r; // raw-f64 wire size of one (d, r) panel

    // --- 1. federated vs gossip ------------------------------------------
    println!("\n[1] federated single round vs gossip  (d={d} r={r} m={m} n={n})");
    let fed = align::procrustes_fix(&panels);
    println!(
        "  federated Alg1 : dist {:.4}   comm {} B, 1 round",
        dist2(&fed, &truth),
        m * panel_bytes
    );
    for (name, topo) in [("ring", Topology::Ring), ("complete", Topology::Complete)] {
        let res = gossip_align(panels.clone(), &topo, 40, 1e-3, WireCodec::F64, None);
        let worst = res
            .panels
            .iter()
            .map(|p| dist2(p, &truth))
            .fold(0.0f64, f64::max);
        println!(
            "  gossip {name:<8}: dist {:.4} (worst node)   comm {} B, {} rounds, final spread {:.4}",
            worst,
            res.bytes,
            res.rounds,
            spread(&res.panels)
        );
    }

    // --- 2. panel compression ---------------------------------------------
    println!("\n[2] upload compression");
    println!("  f64 (baseline) : dist {:.4}   {} B/panel", dist2(&fed, &truth), panel_bytes);
    for codec in [Codec::F16, Codec::Int8] {
        let compressed: Vec<Mat> = panels
            .iter()
            .map(|p| dequantize_panel(&quantize_panel(p, codec)))
            .collect();
        let est = align::procrustes_fix(&compressed);
        let bytes = quantize_panel(&panels[0], codec).wire_bytes();
        println!(
            "  {codec:?}           : dist {:.4}   {} B/panel",
            dist2(&est, &truth),
            bytes
        );
    }

    // --- 3. Frequent Directions -------------------------------------------
    println!("\n[3] Frequent Directions sketch upload vs panel upload");
    for &l in &[r + 2, 2 * r, 4 * r] {
        let mut merged = FrequentDirections::new(l, d);
        let mut bytes = 0;
        for x in &samples {
            let mut fd = FrequentDirections::new(l, d);
            fd.insert_all(x);
            bytes += fd.wire_bytes();
            merged.merge(&fd);
        }
        let est = merged.leading_subspace(r);
        println!(
            "  FD l={l:<3}       : dist {:.4}   {} B total (panels: {} B)",
            dist2(&est, &truth),
            bytes,
            m * panel_bytes
        );
    }

    // --- 4. local solver at small gaps -------------------------------------
    println!("\n[4] local solver at small eigengap (d={d}, gap=0.02)");
    let tiny = SpectrumModel::M1 { r, lambda_lo: 0.9, lambda_hi: 1.0, delta: 0.02 };
    let cov2 = CovModel::draw(&tiny, d, &mut rng);
    let sigma = cov2.sigma();
    let iters = if quick_mode() { 3 } else { 7 };
    for (name, solver) in [
        ("orth-iter (native)", &NativeEngine { steps: 300 } as &dyn LocalSolver),
        ("shift-invert [23]", &ShiftInvertEngine::default() as &dyn LocalSolver),
    ] {
        let mut dist = 0.0;
        let res = bench(name, 1, iters, || {
            let mut r2 = Pcg64::seed(3);
            let v = solver.leading_subspace(&sigma, r, &mut r2);
            dist = dist2(&v, &cov2.principal_subspace());
        });
        println!(
            "  {name:<20}: {:>10}/solve, dist {:.2e}",
            fmt_time(res.median_s),
            dist
        );
    }
    println!("\n  takeaways: one federated round matches gossip-until-mixed at a fraction");
    println!("  of the bytes; f16 cuts upload size 4x for free (int8: 8x); FD sketches");
    println!("  trade bytes for bias; shift-invert wins local solves only at tiny gaps.");
}
