//! Protocol-engine throughput bench (DESIGN.md S15): wall-clock per
//! cluster run for each round protocol at a fixed K, on identical worker
//! observations, f64 and int8 codecs. The spread isolates what each
//! protocol adds on top of the shared round skeleton — qpower pays one
//! operator apply per worker per round, sanger adds the Hebbian update
//! GEMMs, deepca adds QR + tracking plus leader-side FastMix. Run:
//! `cargo bench --bench bench_rounds` (add `-- --quick` to smoke,
//! `-- --json BENCH_rounds.json` for machine-readable output; under a
//! blanket `cargo bench`, `--json-rounds <path>` takes precedence so
//! this bench does not clobber another target's artifact).

use std::sync::Arc;

use deigen::benchutil::{bench, header, quick_mode, report, JsonSink};
use deigen::coordinator::{
    run_cluster_faulty, run_cluster_journaled, ClusterConfig, FaultPlan, FaultRunConfig,
    ProtocolKind, RobustMode, RobustPolicy, Topology, WireCodec, WorkerData,
};
use deigen::linalg::gemm::matmul;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;

fn observations(seed: u64, d: usize, r: usize, m: usize, noise: f64) -> Vec<Mat> {
    let mut rng = Pcg64::seed(seed);
    let q = rng.haar_orthogonal(d);
    let evs: Vec<f64> = (0..d).map(|i| if i < r { 1.0 } else { 0.3 }).collect();
    let x = matmul(&Mat::from_fn(d, d, |i, j| q[(i, j)] * evs[j]), &q.transpose());
    (0..m)
        .map(|_| {
            let mut e = rng.normal_mat(d, d).scale(noise);
            e.symmetrize();
            x.add(&e)
        })
        .collect()
}

fn main() {
    header("rounds: protocol engine throughput per cluster run");
    let args: Vec<String> = std::env::args().collect();
    let json_path = ["--json-rounds", "--json"].iter().find_map(|flag| {
        args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).cloned()
    });
    let mut sink = JsonSink::with_path(json_path);

    let (d, r, m, k, iters) = if quick_mode() {
        (32usize, 3usize, 6usize, 2usize, 3usize)
    } else {
        (64, 5, 16, 3, 7)
    };
    let obs = observations(11, d, r, m, 0.08);
    let mk = || -> Vec<WorkerData> { obs.iter().map(|o| WorkerData::dense(o.clone())).collect() };
    let solver = Arc::new(NativeEngine::default());
    let fc = FaultRunConfig::full(m);

    let protocols: [(&str, ProtocolKind, usize); 4] = [
        ("oneshot", ProtocolKind::OneShot, k),
        ("qpower", ProtocolKind::QPower { rounds: k, tol: 0.0 }, 0),
        (
            "sanger",
            ProtocolKind::Sanger { rounds: k, step: 0.3, topology: Topology::Ring, tol: 0.0 },
            0,
        ),
        (
            "deepca",
            ProtocolKind::DeepCa { rounds: k, fastmix: 3, topology: Topology::Ring, tol: 0.0 },
            0,
        ),
    ];
    for (name, protocol, refine) in &protocols {
        for codec in [WireCodec::F64, WireCodec::Int8] {
            let cfg = ClusterConfig {
                r,
                refine_rounds: *refine,
                protocol: protocol.clone(),
                codec,
                seed: 11,
                ..Default::default()
            };
            let res = bench(
                &format!("{name:<7} {} m={m} d={d} K={k}", codec.name()),
                1,
                iters,
                || {
                    let out = run_cluster_faulty(mk(), solver.clone(), &cfg, &fc);
                    std::hint::black_box(out.estimate);
                },
            );
            report(&res);
            sink.record(&res, None);
        }
    }

    // robust-merge overhead probe: the same qpower run with the
    // reputation gate screening a corrupt minority, vs the plain merge —
    // the delta is the per-round Procrustes screening + scoring cost
    let byz_fc = FaultRunConfig {
        plan: FaultPlan::parse(&format!("byz={}:rotate", (m / 2).saturating_sub(1).max(1)))
            .expect("byz spec")
            .seeded(11),
        ..FaultRunConfig::full(m)
    };
    for (label, robust) in [
        ("plain ", RobustPolicy::off()),
        ("screen", RobustPolicy::with_mode(RobustMode::Screen)),
    ] {
        let cfg = ClusterConfig {
            r,
            protocol: ProtocolKind::QPower { rounds: k, tol: 0.0 },
            seed: 11,
            robust,
            ..Default::default()
        };
        let res = bench(
            &format!("qpower+byz {label} m={m} d={d} K={k}"),
            1,
            iters,
            || {
                let out = run_cluster_faulty(mk(), solver.clone(), &cfg, &byz_fc);
                std::hint::black_box(out.estimate);
            },
        );
        report(&res);
        sink.record(&res, None);
    }

    // journaling-overhead probe (DESIGN.md S17): the same qpower run with
    // a per-round durable checkpoint (serialize + checksum + fsync) vs
    // none — the delta divided by K+1 is the cost of one checkpoint
    let jpath =
        std::env::temp_dir().join(format!("deigen_bench_rounds_{}.journal", std::process::id()));
    for (label, journal) in [("off", false), ("on ", true)] {
        let cfg = ClusterConfig {
            r,
            protocol: ProtocolKind::QPower { rounds: k, tol: 0.0 },
            seed: 11,
            ..Default::default()
        };
        let res = bench(&format!("qpower journal={label} m={m} d={d} K={k}"), 1, iters, || {
            let out = if journal {
                run_cluster_journaled(mk(), solver.clone(), &cfg, &fc, &jpath)
                    .expect("journaled bench run")
            } else {
                run_cluster_faulty(mk(), solver.clone(), &cfg, &fc)
            };
            std::hint::black_box(out.estimate);
        });
        report(&res);
        sink.record(&res, None);
    }
    let _ = std::fs::remove_file(&jpath);
    sink.finish();
}
