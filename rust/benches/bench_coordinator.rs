//! End-to-end coordinator benchmark: full threaded leader/worker rounds
//! (local solve + upload + alignment) across m, refinement depth and
//! network models. This is the paper's systems story quantified: one round
//! of (d, r)-panel uploads vs multi-round refinement vs what shipping raw
//! covariances (the centralized alternative) would cost on the wire.
//! Run: `cargo bench --bench bench_coordinator`

use std::sync::Arc;

use deigen::benchutil::{bench, fmt_time, header};
use deigen::coordinator::{run_cluster, ClusterConfig, NetworkModel, WorkerData};
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;
use deigen::synth::{CovModel, SpectrumModel};

fn make_workers(cov: &CovModel, n: usize, m: usize, seed: u64) -> Vec<WorkerData> {
    let mut rng = Pcg64::seed(seed);
    (0..m)
        .map(|i| {
            WorkerData::dense(CovModel::empirical_cov(&cov.sample(n, &mut rng.split(i as u64))))
        })
        .collect()
}

fn main() {
    header("coordinator end-to-end");
    let (d, r, n) = (100usize, 8usize, 300usize);
    let mut rng = Pcg64::seed(5);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);

    println!("  d={d} r={r} n={n}\n");
    println!("  m    refine   wall(median)   bytes up      bytes down    sim WAN     sim DC");
    for &m in &[8usize, 16, 32] {
        for &refine in &[0usize, 2] {
            let mut last = None;
            let res = bench(&format!("m={m} refine={refine}"), 1, 5, || {
                let workers = make_workers(&cov, n, m, 42);
                let cfg = ClusterConfig { r, refine_rounds: refine, seed: 7, ..Default::default() };
                last = Some(run_cluster(workers, Arc::new(NativeEngine::default()), &cfg));
            });
            let out = last.unwrap();
            let wan = NetworkModel::wan();
            let dc = NetworkModel::datacenter();
            // recompute simulated times from the snapshot
            let sim = |net: &NetworkModel| out.comm.simulated_time(net);
            println!(
                "  {m:>2}   {refine:>6}   {:>12}   {:>10}B   {:>10}B   {:>8}   {:>8}",
                fmt_time(res.median_s),
                out.comm.bytes_up,
                out.comm.bytes_down,
                fmt_time(sim(&wan)),
                fmt_time(sim(&dc)),
            );
        }
    }

    // the communication comparison the single-round design wins:
    // uploading panels (8dr bytes raw-f64) vs uploading raw local
    // covariances (8d^2 bytes, what a "send everything to the leader"
    // design needs) — and the wire codecs shrink the panel side further
    let panel = 8 * d * r;
    let cov_bytes = 8 * d * d;
    println!(
        "\n  per-node upload: aligned panel {panel} B vs raw covariance {cov_bytes} B ({}x saving)",
        cov_bytes / panel
    );
    println!("  paper claim: ONE round of (d, r) panels matches centralized accuracy.");
}
