//! Benchmarks for the blocked spectral backend (DESIGN.md S1): the
//! full-spectrum blocked-vs-naive anchor, the top-r-vs-full anchor at the
//! dispatch-relevant shape, and an end-to-end Frequent-Directions shrink
//! probe (the FD sketch shrinks through the same backend on every buffer
//! fill). Run: `cargo bench --bench bench_eig` (add `-- --quick` to
//! smoke, `-- --json BENCH_eig.json` for machine-readable output).
//! Under a blanket `cargo bench` that already carries bench_linalg's
//! `--json` flag, pass `--json-eig <path>` as well — it takes
//! precedence here, so one blanket invocation emits both artifacts
//! without either bench clobbering the other's file.
//!
//! Quick mode shrinks the problem sizes as well as the iteration counts:
//! a d = 1024 naive eigensolve has no place in a CI smoke run.

use deigen::benchutil::{bench, header, quick_mode, report, JsonSink};
use deigen::linalg::eig::{sym_eig, sym_eig_naive, sym_eig_top_r, top_eigvals};
use deigen::linalg::gemm::matmul;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::sketch::FrequentDirections;

fn gapped_sym(rng: &mut Pcg64, d: usize, r: usize) -> Mat {
    // planted leading block with a clean gap, trailing geometric decay —
    // the covariance shape every layer of the pipeline feeds the solver
    let q = rng.haar_orthogonal(d);
    let evs: Vec<f64> = (0..d)
        .map(|i| if i < r { 1.0 - 0.02 * i as f64 } else { 0.5 * 0.99f64.powi((i - r) as i32) })
        .collect();
    let scaled = Mat::from_fn(d, d, |i, j| q[(i, j)] * evs[j]);
    matmul(&scaled, &q.transpose())
}

fn main() {
    header("blocked spectral backend");
    // `--json-eig` wins over `--json` so a blanket `cargo bench` run can
    // route this bench and bench_linalg to different files
    let args: Vec<String> = std::env::args().collect();
    let json_path = ["--json-eig", "--json"].iter().find_map(|flag| {
        args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).cloned()
    });
    let mut sink = JsonSink::with_path(json_path);
    let mut rng = Pcg64::seed(0xe16);
    let quick = quick_mode();

    // --- full-spectrum anchor: blocked vs the retained scalar path ---
    // the acceptance claim is that the blocked path wins at d >= 256
    let d_full = if quick { 192 } else { 512 };
    let a = gapped_sym(&mut rng, d_full, 16);
    let rb = bench(&format!("sym_eig blocked d={d_full}"), 1, 5, || {
        std::hint::black_box(sym_eig(&a));
    });
    let rn = bench(&format!("sym_eig naive   d={d_full}"), 1, 5, || {
        std::hint::black_box(sym_eig_naive(&a));
    });
    report(&rb);
    report(&rn);
    println!(
        "      -> blocked/naive speedup: {:.2}x (claim: blocked wins at d >= 256)",
        rn.median_s / rb.median_s
    );
    sink.record(&rb, None);
    sink.record(&rn, None);

    // --- top-r vs full anchor at the headline shape d=1024 / r=16 ---
    let (d_top, r_top) = if quick { (256, 16) } else { (1024, 16) };
    let c = gapped_sym(&mut rng, d_top, r_top);
    let rt = bench(&format!("sym_eig_top_r d={d_top} r={r_top}"), 1, 5, || {
        std::hint::black_box(sym_eig_top_r(&c, r_top));
    });
    let rf = bench(&format!("sym_eig full  d={d_top}"), 1, 3, || {
        std::hint::black_box(sym_eig(&c));
    });
    let rv = bench(&format!("top_eigvals   d={d_top} k={r_top}"), 1, 5, || {
        std::hint::black_box(top_eigvals(&c, r_top));
    });
    report(&rt);
    report(&rf);
    report(&rv);
    println!(
        "      -> top-r speedup over full: {:.2}x (values-only: {:.2}x)",
        rf.median_s / rt.median_s,
        rf.median_s / rv.median_s
    );
    sink.record(&rt, None);
    sink.record(&rf, None);
    sink.record(&rv, None);

    // --- FD-shrink end-to-end probe: stream n rows through a sketch ---
    // every l-th insert triggers a shrink, i.e. an l x l eigensolve plus
    // the U^T B rebuild GEMM — the sketch codec's hot loop
    let (n_rows, d_fd, l_fd) = if quick { (256, 128, 32) } else { (2048, 512, 64) };
    let x = rng.normal_mat(n_rows, d_fd);
    let rs = bench(&format!("fd shrink stream n={n_rows} d={d_fd} l={l_fd}"), 1, 5, || {
        let mut fd = FrequentDirections::new(l_fd, d_fd);
        fd.insert_all(&x);
        std::hint::black_box(fd.covariance_estimate());
    });
    report(&rs);
    sink.record(&rs, None);

    sink.finish();
}
