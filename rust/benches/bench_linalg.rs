//! Microbenchmarks for the native linalg substrate — the L3 hot paths
//! profiled in EXPERIMENTS.md §Perf: GEMM/SYRK (covariance formation),
//! QR, the symmetric eigensolver, Jacobi SVD and the two polar routes.
//! Run: `cargo bench --bench bench_linalg` (add `-- --quick` to smoke).

use deigen::benchutil::{bench, header, report};
use deigen::linalg::eig::sym_eig;
use deigen::linalg::gemm::{matmul, matmul_naive, syrk_scaled};
use deigen::linalg::procrustes::{polar_newton_schulz, polar_svd};
use deigen::linalg::qr::thin_qr;
use deigen::linalg::svd::svd;
use deigen::rng::Pcg64;

fn main() {
    header("linalg substrate");
    let mut rng = Pcg64::seed(1);

    for &n in &[64usize, 128, 256] {
        let a = rng.normal_mat(n, n);
        let b = rng.normal_mat(n, n);
        let flops = 2.0 * (n as f64).powi(3);
        let r = bench(&format!("matmul {n}x{n}x{n}"), 2, 9, || {
            std::hint::black_box(matmul(&a, &b));
        });
        report(&r);
        println!("      -> {:.2} GFLOP/s", flops / r.median_s / 1e9);
    }

    // blocked vs naive at one size (the §Perf before/after anchor)
    let a = rng.normal_mat(192, 192);
    let b = rng.normal_mat(192, 192);
    let rb = bench("matmul blocked 192", 2, 9, || {
        std::hint::black_box(matmul(&a, &b));
    });
    let rn = bench("matmul naive   192", 2, 9, || {
        std::hint::black_box(matmul_naive(&a, &b));
    });
    report(&rb);
    report(&rn);
    println!("      -> blocked/naive speedup: {:.2}x", rn.median_s / rb.median_s);

    for &(n, d) in &[(500usize, 100usize), (1000, 300)] {
        let x = rng.normal_mat(n, d);
        let r = bench(&format!("syrk (cov) n={n} d={d}"), 1, 7, || {
            std::hint::black_box(syrk_scaled(&x, n as f64));
        });
        report(&r);
    }

    for &(m, k) in &[(300usize, 16usize), (300, 64)] {
        let x = rng.normal_mat(m, k);
        report(&bench(&format!("thin_qr {m}x{k}"), 2, 9, || {
            std::hint::black_box(thin_qr(&x));
        }));
    }

    for &d in &[100usize, 250] {
        let mut s = rng.normal_mat(d, d);
        s.symmetrize();
        report(&bench(&format!("sym_eig d={d}"), 1, 5, || {
            std::hint::black_box(sym_eig(&s));
        }));
    }

    for &(m, k) in &[(64usize, 16usize), (128, 32)] {
        let x = rng.normal_mat(m, k);
        report(&bench(&format!("jacobi svd {m}x{k}"), 2, 7, || {
            std::hint::black_box(svd(&x));
        }));
    }

    for &r in &[8usize, 16, 32] {
        let q = rng.haar_orthogonal(r);
        let a = q.add(&rng.normal_mat(r, r).scale(0.05));
        let rs = bench(&format!("polar svd    r={r}"), 3, 11, || {
            std::hint::black_box(polar_svd(&a));
        });
        let rn = bench(&format!("polar newton r={r}"), 3, 11, || {
            std::hint::black_box(polar_newton_schulz(&a, 18));
        });
        report(&rs);
        report(&rn);
    }
}
