//! Microbenchmarks for the native linalg substrate — the L3 hot paths
//! profiled in EXPERIMENTS.md §Perf: packed GEMM vs the naive oracle,
//! SYRK (covariance formation), per-call pool fan-out overhead, QR, the
//! symmetric eigensolver, Jacobi SVD and the two polar routes.
//! Run: `cargo bench --bench bench_linalg` (add `-- --quick` to smoke,
//! `-- --json BENCH_linalg.json` for machine-readable output).

use deigen::benchutil::{bench, gflops, header, report, JsonSink};
use deigen::linalg::eig::sym_eig;
use deigen::linalg::gemm::{matmul, matmul_naive, syrk_scaled};
use deigen::linalg::pool;
use deigen::linalg::procrustes::{polar_newton_schulz, polar_svd};
use deigen::linalg::qr::thin_qr;
use deigen::linalg::svd::svd;
use deigen::rng::Pcg64;

fn main() {
    header("linalg substrate");
    let mut sink = JsonSink::from_args();
    let mut rng = Pcg64::seed(1);

    for &n in &[64usize, 128, 256] {
        let a = rng.normal_mat(n, n);
        let b = rng.normal_mat(n, n);
        let flops = 2.0 * (n as f64).powi(3);
        let r = bench(&format!("matmul {n}x{n}x{n}"), 2, 9, || {
            std::hint::black_box(matmul(&a, &b));
        });
        report(&r);
        println!("      -> {:.2} GFLOP/s", gflops(&r, flops));
        sink.record(&r, Some(flops));
    }

    // packed vs naive at the §Perf anchor size (the acceptance gate is
    // >= 2x median GFLOP/s for the packed kernel at 256^3)
    let a = rng.normal_mat(256, 256);
    let b = rng.normal_mat(256, 256);
    let flops = 2.0 * 256f64.powi(3);
    let rb = bench("matmul packed 256x256x256", 2, 9, || {
        std::hint::black_box(matmul(&a, &b));
    });
    let rn = bench("matmul naive  256x256x256", 2, 9, || {
        std::hint::black_box(matmul_naive(&a, &b));
    });
    report(&rb);
    report(&rn);
    println!(
        "      -> packed/naive speedup: {:.2}x ({:.2} vs {:.2} GFLOP/s)",
        rn.median_s / rb.median_s,
        gflops(&rb, flops),
        gflops(&rn, flops)
    );
    sink.record(&rb, Some(flops));
    sink.record(&rn, Some(flops));

    // per-call fan-out overhead: repeated calls at a shape that sits
    // exactly at PAR_THRESHOLD (128^3 = 2^21), so every call takes the
    // pooled path. The persistent pool prices a repeat call at the work
    // itself; the old thread::scope path paid ~50us x threads of spawn
    // tax per call, visible as pooled slower than forced-serial here.
    let a = rng.normal_mat(128, 128);
    let b = rng.normal_mat(128, 128);
    let flops = 2.0 * 128f64.powi(3);
    let rp = bench("matmul 128^3 pooled, repeated calls", 4, 15, || {
        std::hint::black_box(matmul(&a, &b));
    });
    let rs = pool::with_threads(1, || {
        bench("matmul 128^3 forced single-thread", 4, 15, || {
            std::hint::black_box(matmul(&a, &b));
        })
    });
    report(&rp);
    report(&rs);
    println!(
        "      -> pooled speedup over forced-serial: {:.2}x (>= 1x means no spawn tax)",
        rs.median_s / rp.median_s
    );
    sink.record(&rp, Some(flops));
    sink.record(&rs, Some(flops));

    for &(n, d) in &[(500usize, 100usize), (1000, 300)] {
        let x = rng.normal_mat(n, d);
        // upper-triangle SYRK: ~n*d^2 multiply-adds instead of 2*n*d^2
        let flops = (n * d * d) as f64;
        let r = bench(&format!("syrk (cov) n={n} d={d}"), 1, 7, || {
            std::hint::black_box(syrk_scaled(&x, n as f64));
        });
        report(&r);
        sink.record(&r, Some(flops));
    }

    for &(m, k) in &[(300usize, 16usize), (300, 64)] {
        let x = rng.normal_mat(m, k);
        let r = bench(&format!("thin_qr {m}x{k}"), 2, 9, || {
            std::hint::black_box(thin_qr(&x));
        });
        report(&r);
        sink.record(&r, None);
    }

    for &d in &[100usize, 250] {
        let mut s = rng.normal_mat(d, d);
        s.symmetrize();
        let r = bench(&format!("sym_eig d={d}"), 1, 5, || {
            std::hint::black_box(sym_eig(&s));
        });
        report(&r);
        sink.record(&r, None);
    }

    for &(m, k) in &[(64usize, 16usize), (128, 32)] {
        let x = rng.normal_mat(m, k);
        let r = bench(&format!("jacobi svd {m}x{k}"), 2, 7, || {
            std::hint::black_box(svd(&x));
        });
        report(&r);
        sink.record(&r, None);
    }

    for &r in &[8usize, 16, 32] {
        let q = rng.haar_orthogonal(r);
        let a = q.add(&rng.normal_mat(r, r).scale(0.05));
        let rs = bench(&format!("polar svd    r={r}"), 3, 11, || {
            std::hint::black_box(polar_svd(&a));
        });
        let rn = bench(&format!("polar newton r={r}"), 3, 11, || {
            std::hint::black_box(polar_newton_schulz(&a, 18));
        });
        report(&rs);
        report(&rn);
        sink.record(&rs, None);
        sink.record(&rn, None);
    }

    sink.finish();
}
