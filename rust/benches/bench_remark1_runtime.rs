//! **Remark 1** reproduction: coordinator-side runtime of Procrustes fixing
//! (m Procrustes problems, O(m r^2 d) total) vs spectral-projector
//! averaging (Fan et al. [20]; forming/иterating on the d x d averaged
//! projector, O(m r^2 d) *per orthogonal-iteration step* plus the
//! eigensolve). The paper's claim: the whole Procrustes pass costs about
//! one single step of the iterative method — so the ratio should grow with
//! the number of iteration steps the eigensolve needs.
//! Run: `cargo bench --bench bench_remark1_runtime`

use deigen::align;
use deigen::benchutil::{bench, fmt_time, header};
use deigen::linalg::gemm::{a_bt, matmul};
use deigen::linalg::orthiter::orth_iter;
use deigen::linalg::qr::orthonormalize;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;

fn noisy_locals(rng: &mut Pcg64, d: usize, r: usize, m: usize) -> Vec<Mat> {
    let truth = rng.haar_stiefel(d, r);
    (0..m)
        .map(|_| {
            let z = rng.haar_orthogonal(r);
            orthonormalize(&matmul(&truth, &z).add(&rng.normal_mat(d, r).scale(0.05)))
        })
        .collect()
}

fn main() {
    header("Remark 1: Procrustes fixing vs projector averaging runtime");
    let mut rng = Pcg64::seed(3);
    let (d, m) = (300usize, 50usize);

    println!("  d={d} m={m}");
    println!("  r    procrustes(all m)   projector(avg+eig)   1 orth-iter step   ratio proj/procr");
    for &r in &[4usize, 8, 16, 32] {
        let locals = noisy_locals(&mut rng, d, r, m);

        let t_proc = bench(&format!("procrustes r={r}"), 1, 5, || {
            std::hint::black_box(align::procrustes_fix(&locals));
        });

        let t_proj = bench(&format!("projector r={r}"), 1, 3, || {
            std::hint::black_box(align::projector_average(&locals));
        });

        // one orthogonal-iteration step over the averaged projector — the
        // per-step cost Remark 1 counts for the iterative alternative
        let mut p = Mat::zeros(d, d);
        for v in &locals {
            p.axpy(1.0 / m as f64, &a_bt(v, v));
        }
        let v0 = rng.normal_mat(d, r);
        let t_step = bench(&format!("orth-iter step r={r}"), 1, 5, || {
            std::hint::black_box(orth_iter(&p, &v0, 1));
        });

        println!(
            "  {r:>2}   {:>17}   {:>18}   {:>16}   {:>8.2}x",
            fmt_time(t_proc.median_s),
            fmt_time(t_proj.median_s),
            fmt_time(t_step.median_s),
            t_proj.median_s / t_proc.median_s,
        );
    }
    println!("\n  paper shape: whole Procrustes pass ~ O(m r^2 d) — comparable to ONE");
    println!("  step of the iterative projector method; full projector solve costs many steps.");
}
