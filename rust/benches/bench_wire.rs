//! Wire-codec encode/decode throughput: how fast each [`WireCodec`] turns
//! a (d, r) panel into wire bytes and back, and what it costs on the
//! wire. The decode column is the leader's per-panel cost in round 1, so
//! it bounds how far transport compression can be pushed before the
//! leader becomes compute-bound instead of bandwidth-bound.
//! Run: `cargo bench --bench bench_wire`

use deigen::benchutil::{bench, fmt_time, header};
use deigen::coordinator::WireCodec;
use deigen::rng::Pcg64;

/// Human bytes-per-second formatting.
fn fmt_rate(bps: f64) -> String {
    if bps >= 1e9 {
        format!("{:.2}GB/s", bps / 1e9)
    } else if bps >= 1e6 {
        format!("{:.2}MB/s", bps / 1e6)
    } else {
        format!("{:.0}kB/s", bps / 1e3)
    }
}

fn main() {
    header("wire codec encode/decode");
    let mut rng = Pcg64::seed(9);
    for &(d, r) in &[(256usize, 8usize), (512, 16)] {
        let panel = rng.haar_stiefel(d, r);
        let raw = 8 * d * r;
        println!("\n  panel {d}x{r} ({raw} B raw)");
        println!("  codec    wire bytes   ratio      encode            decode");
        for codec in [
            WireCodec::F64,
            WireCodec::F16,
            WireCodec::Int8,
            WireCodec::FdSketch { l: r / 2 },
        ] {
            let encoded = codec.encode(&panel);
            let wire = encoded.wire_bytes();
            let enc = bench(&format!("{} encode {d}x{r}", codec.name()), 2, 9, || {
                std::hint::black_box(codec.encode(&panel));
            });
            let dec = bench(&format!("{} decode {d}x{r}", codec.name()), 2, 9, || {
                std::hint::black_box(encoded.decode());
            });
            println!(
                "  {:<6}   {:>8} B   {:>5.2}x   {:>9} ({:>9})   {:>9} ({:>9})",
                codec.name(),
                wire,
                raw as f64 / wire as f64,
                fmt_time(enc.median_s),
                fmt_rate(raw as f64 / enc.median_s.max(1e-12)),
                fmt_time(dec.median_s),
                fmt_rate(raw as f64 / dec.median_s.max(1e-12)),
            );
        }
    }
    println!("\n  quantizers encode at memory speed; the FD sketch pays a d x d");
    println!("  eigendecomposition on decode — cheap for the leader, but the reason");
    println!("  it is the aggressive (not the default) end of the sweep.");
}
