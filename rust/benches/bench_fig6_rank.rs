//! Regenerates paper experiment **fig6** as a bench target: runs the same
//! sweep as `deigen exp fig6` (quick-scaled under `-- --quick`) and reports
//! wall-clock. The printed rows ARE the paper's series; see
//! rust/src/experiments/ for the parameters and EXPERIMENTS.md for the
//! paper-vs-measured comparison.

use deigen::benchutil::header;
use deigen::config::RunOptions;

fn main() {
    header("paper experiment fig6");
    // Bench targets time the harness; they run the quick-scaled sweep by
    // default so `cargo bench` stays bounded. Set DEIGEN_BENCH_FULL=1 to
    // regenerate the paper-size series here instead of via `deigen exp`.
    let full = std::env::var("DEIGEN_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let opts = RunOptions {
        seed: 20200504,
        out_dir: "results/bench".to_string(),
        trials: if full { 0 } else { 1 },
        quick: !full,
    };
    let t0 = std::time::Instant::now();
    deigen::experiments::run("fig6", &opts).expect("experiment failed");
    println!("\n  bench_fig6_rank: regenerated fig6 in {:?}", t0.elapsed());
}
