//! Estimator benchmarks: wall-clock of Algorithm 1/2, naive averaging,
//! sign fixing, projector averaging and the robust median variant across
//! (d, r, m) — the coordinator-side cost the paper's Remark 1 analyses.
//! Run: `cargo bench --bench bench_alignment`

use deigen::align;
use deigen::benchutil::{bench, header, report};
use deigen::linalg::gemm::matmul;
use deigen::linalg::qr::orthonormalize;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;

fn noisy_locals(rng: &mut Pcg64, d: usize, r: usize, m: usize) -> Vec<Mat> {
    let truth = rng.haar_stiefel(d, r);
    (0..m)
        .map(|_| {
            let z = rng.haar_orthogonal(r);
            orthonormalize(&matmul(&truth, &z).add(&rng.normal_mat(d, r).scale(0.05)))
        })
        .collect()
}

fn main() {
    header("alignment estimators");
    let mut rng = Pcg64::seed(2);

    for &(d, r, m) in &[(100usize, 4usize, 25usize), (300, 8, 50), (300, 16, 50)] {
        let locals = noisy_locals(&mut rng, d, r, m);
        println!("--- d={d} r={r} m={m} ---");
        report(&bench("procrustes_fix (Alg 1)", 1, 7, || {
            std::hint::black_box(align::procrustes_fix(&locals));
        }));
        report(&bench("iterative_refinement x5 (Alg 2)", 1, 5, || {
            std::hint::black_box(align::iterative_refinement(&locals, 5));
        }));
        report(&bench("naive_average", 1, 7, || {
            std::hint::black_box(align::naive_average(&locals));
        }));
        report(&bench("projector_average (Fan [20])", 1, 5, || {
            std::hint::black_box(align::projector_average(&locals));
        }));
        report(&bench("coordinate_median_fix (robust)", 1, 3, || {
            std::hint::black_box(align::coordinate_median_fix(&locals));
        }));
    }

    // r = 1: Procrustes must collapse to (cheap) sign fixing
    let locals = noisy_locals(&mut rng, 300, 1, 50);
    println!("--- d=300 r=1 m=50 ---");
    report(&bench("sign_fix_average (Garber [24])", 1, 9, || {
        std::hint::black_box(align::sign_fix_average(&locals));
    }));
    report(&bench("procrustes_fix r=1", 1, 9, || {
        std::hint::black_box(align::procrustes_fix(&locals));
    }));
}
