//! Benchmarks for the matrix-free operator data plane (DESIGN.md S13):
//! the GramOp-vs-dense local solve at the headline tall-shard shape
//! (d = 2048, n = 256 — the regime where forming the d×d covariance
//! dwarfs the solve), and the sparse KatzOp against the dense power loop
//! it replaced. Run: `cargo bench --bench bench_ops` (add `-- --quick` to
//! smoke, `-- --json BENCH_ops.json` for machine-readable output). Under
//! a blanket `cargo bench` that already carries `--json` for
//! bench_linalg, pass `--json-ops <path>` — it takes precedence here, so
//! one blanket invocation emits every artifact without clobbering.

use deigen::benchutil::{bench, header, quick_mode, report, JsonSink};
use deigen::graph::sbm;
use deigen::linalg::gemm::{matmul, syrk_scaled};
use deigen::linalg::symop::{GramOp, KatzOp, SymOp};
use deigen::rng::Pcg64;
use deigen::runtime::{LocalSolver, NativeEngine};

fn main() {
    header("operator data plane");
    let args: Vec<String> = std::env::args().collect();
    // `--json-ops` wins over `--json` so a blanket `cargo bench` run can
    // route this bench and bench_linalg to different files
    let json_path = ["--json-ops", "--json"].iter().find_map(|flag| {
        args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).cloned()
    });
    let mut sink = JsonSink::with_path(json_path);
    let quick = quick_mode();
    let mut rng = Pcg64::seed(0x0b5);

    // --- GramOp vs dense local solve: the acceptance anchor -------------
    // dense path = form X^T X / n (O(n d^2) SYRK) + orthogonal iteration
    // on the d x d plane (O(d^2 r) per step); GramOp path = two thin
    // GEMMs per step (O(n d r)), nothing formed. At n << d the dense
    // route pays ~d/n more per step plus the formation — the claim is
    // >= 5x end to end at (d, n) = (2048, 256).
    let (d, n, r) = if quick { (384usize, 96usize, 8usize) } else { (2048, 256, 8) };
    let x = rng.normal_mat(n, d);
    let solver = NativeEngine::default();
    let iters = if quick { 2 } else { 5 };
    let rd = bench(&format!("dense solve  d={d} n={n} r={r} (SYRK + iter)"), 1, iters, || {
        let mut solve_rng = Pcg64::seed(7);
        let c = syrk_scaled(&x, n as f64);
        std::hint::black_box(solver.leading_subspace(&c, r, &mut solve_rng));
    });
    let rg = bench(&format!("GramOp solve d={d} n={n} r={r} (matrix-free)"), 1, iters, || {
        let mut solve_rng = Pcg64::seed(7);
        std::hint::black_box(solver.leading_subspace_op(&GramOp::new(&x), r, &mut solve_rng));
    });
    report(&rd);
    report(&rg);
    let speedup = rd.median_s / rg.median_s;
    println!(
        "      -> GramOp/dense local-solve speedup: {speedup:.2}x \
         (claim: >= 5x at d=2048/n=256)"
    );
    sink.record(&rd, None);
    sink.record(&rg, None);

    // --- KatzOp vs the dense power loop ---------------------------------
    // dense Katz needs `terms` n x n GEMMs per proximity build (O(n^3)
    // each); KatzOp runs the whole series per panel product in
    // O(|E| * r * terms). We time one dense power term and the full
    // sparse series, then compare the series costs.
    let (nk, terms, rk) = if quick { (512usize, 24usize, 16usize) } else { (4096, 24, 16) };
    let mut grng = Pcg64::seed(0x9a_f);
    // sparse regime: average degree ~12 independent of n
    let g = sbm(nk, 4, 18.0 / nk as f64, 6.0 / nk as f64, &mut grng);
    let v = grng.normal_mat(nk, rk);
    let op = KatzOp::new(g.n, &g.edges, 0.02, terms);
    let rs = bench(
        &format!("KatzOp apply n={nk} |E|={} r={rk} terms={terms}", g.m()),
        1,
        if quick { 2 } else { 5 },
        || {
            std::hint::black_box(op.apply(&v));
        },
    );
    let a = g.adjacency();
    let rp = bench(&format!("dense Katz power term n={nk}"), 0, if quick { 1 } else { 2 }, || {
        std::hint::black_box(matmul(&a, &a));
    });
    report(&rs);
    report(&rp);
    let dense_series = rp.median_s * terms as f64;
    println!(
        "      -> full series: KatzOp {:.3}s vs dense ~{:.3}s ({:.0}x) at n={nk}",
        rs.median_s,
        dense_series,
        dense_series / rs.median_s
    );
    sink.record(&rs, None);
    sink.record(&rp, Some(2.0 * (nk as f64).powi(3)));

    // --- end-to-end embedding at graph scale ----------------------------
    // the workload the dense plane could not represent: HOPE embedding of
    // an n-node graph without an n x n proximity matrix ever existing
    let dim = 16usize;
    let re = bench(&format!("hope_embedding n={nk} dim={dim} (matrix-free)"), 0, 2, || {
        std::hint::black_box(deigen::graph::hope_embedding(&g, dim, 0.02));
    });
    report(&re);
    sink.record(&re, None);

    sink.finish();
}
