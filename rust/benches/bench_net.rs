//! Round-latency bench for the cluster fault plane (DESIGN.md S14): the
//! in-process quorum engine vs the loopback-TCP transport on identical
//! worker data and a clean fault plan, at one round and at three rounds
//! (one local + two refinement). The gap is the real cost of sockets,
//! frames, and thread handoff — the protocol work is byte-identical on
//! both paths. Run: `cargo bench --bench bench_net` (add `-- --quick` to
//! smoke, `-- --json BENCH_net.json` for machine-readable output; under
//! a blanket `cargo bench`, `--json-net <path>` takes precedence so this
//! bench does not clobber another target's artifact). TCP rows are
//! skipped with a note where loopback sockets are unavailable.

use std::sync::Arc;

use deigen::benchutil::{bench, header, quick_mode, report, JsonSink};
use deigen::coordinator::{
    run_cluster_faulty, run_cluster_tcp, ClusterConfig, FaultRunConfig, WorkerData,
};
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;
use deigen::synth::{CovModel, SpectrumModel};

fn shards(seed: u64, d: usize, r: usize, m: usize, n: usize) -> Vec<Mat> {
    let mut rng = Pcg64::seed(seed);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    (0..m)
        .map(|i| CovModel::empirical_cov(&cov.sample(n, &mut rng.split(i as u64))))
        .collect()
}

fn main() {
    header("net: round latency, in-process engine vs loopback TCP");
    let args: Vec<String> = std::env::args().collect();
    let json_path = ["--json-net", "--json"].iter().find_map(|flag| {
        args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).cloned()
    });
    let mut sink = JsonSink::with_path(json_path);

    let (d, r, m, n, seed) = if quick_mode() {
        (24usize, 3usize, 4usize, 150usize, 7u64)
    } else {
        (48, 3, 8, 300, 7)
    };
    let obs = shards(seed, d, r, m, n);
    let mk = || -> Vec<WorkerData> { obs.iter().map(|o| WorkerData::dense(o.clone())).collect() };
    let solver = Arc::new(NativeEngine::default());
    let fc = FaultRunConfig::full(m);
    let tcp_ok = std::net::TcpListener::bind("127.0.0.1:0").is_ok();
    if !tcp_ok {
        println!("  (loopback sockets unavailable; TCP rows skipped)");
    }

    for &(refine, rounds) in &[(0usize, 1usize), (2, 3)] {
        let cfg = ClusterConfig { r, refine_rounds: refine, seed, ..Default::default() };
        let local = bench(&format!("local m={m} d={d} rounds={rounds}"), 1, 7, || {
            let res = run_cluster_faulty(mk(), solver.clone(), &cfg, &fc);
            std::hint::black_box(res.estimate);
        });
        report(&local);
        sink.record(&local, None);
        if tcp_ok {
            let tcp = bench(&format!("tcp   m={m} d={d} rounds={rounds}"), 1, 5, || {
                let res = run_cluster_tcp(mk(), solver.clone(), &cfg, &fc)
                    .expect("loopback TCP run failed");
                std::hint::black_box(res.estimate);
            });
            report(&tcp);
            sink.record(&tcp, None);
            println!(
                "      -> tcp/local: {:.2}x  ({:+.3}ms per run)",
                tcp.median_s / local.median_s.max(1e-12),
                (tcp.median_s - local.median_s) * 1e3
            );
        }
    }
    sink.finish();
}
