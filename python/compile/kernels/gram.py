"""L1 Pallas kernel: tiled Gram / empirical second-moment accumulation.

This is the O(n d^2) hot spot of the local solver (forming the empirical
covariance ``C = (1/n) X^T X`` from the node's sample block ``X`` of shape
(n, d)). The kernel tiles the contraction over samples so each grid step
touches one (block_n, block_d) strip of ``X`` twice — exactly the
HBM->VMEM schedule a TPU wants (see DESIGN.md §Hardware-Adaptation):

  grid = (d/bd_i, d/bd_j, n/bn);     VMEM per step = 2*bn*bd + bd*bd floats

For the default tiles (bn=128, bd=128, fp32) that is ~192 KiB, far below
the ~16 MiB VMEM budget; on a real MXU the inner ``x_i^T @ x_j`` maps to
(128x128)x(128x128) systolic passes at full utilization. Here the kernel
runs under ``interpret=True`` (CPU numpy semantics) so the benefit we test
is *correctness of the schedule*, not wallclock.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_i_ref, x_j_ref, o_ref, *, inv_n: float):
    """One grid step: accumulate ``x_i^T x_j / n`` into the (i, j) out tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = x_i_ref[...]
    xj = x_j_ref[...]
    o_ref[...] += jnp.dot(xi.T, xj, preferred_element_type=o_ref.dtype) * inv_n


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _ceil_to(v: int, b: int) -> int:
    return ((v + b - 1) // b) * b


@functools.partial(jax.jit, static_argnames=("block_n", "block_d"))
def gram(x: jnp.ndarray, *, block_n: int = 128, block_d: int = 128) -> jnp.ndarray:
    """Tiled Pallas Gram matrix: ``(1/n) X^T X`` for ``X`` of shape (n, d).

    Inputs with shapes not divisible by the tile sizes are zero-padded
    (zero rows/columns do not change the sum; the 1/n scale uses the
    *unpadded* n). Always returns a (d, d) float32 result.
    """
    n, d = x.shape
    bn = min(block_n, _ceil_to(n, 8))
    bd = min(block_d, _ceil_to(d, 8))
    np_, dp = _ceil_to(n, bn), _ceil_to(d, bd)
    xp = _pad_to(x.astype(jnp.float32), np_, dp)

    grid = (dp // bd, dp // bd, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_gram_kernel, inv_n=1.0 / n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, dp), jnp.float32),
        interpret=True,
    )(xp, xp)
    return out[:d, :d]
