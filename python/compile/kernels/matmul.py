"""L1 Pallas kernel: tiled dense matmul (used for the ``C @ V`` panel product
inside block orthogonal iteration).

The subspace-iteration step multiplies the (d, d) local Gram matrix with the
current (d, r) basis panel. ``r`` is small (1..64 in the paper), so the tile
schedule keeps a full (block_m, r) output strip resident in VMEM and streams
(block_m, block_k) tiles of ``C``:

  grid = (d/bm, d/bk);   VMEM per step = bm*bk + bk*r + bm*r floats

which is MXU-shaped for bm = bk = 128 (the systolic array's native tile).
Runs under ``interpret=True`` on CPU — see gram.py for rationale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _ceil_to(v: int, b: int) -> int:
    return ((v + b - 1) // b) * b


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def matmul(
    a: jnp.ndarray, b: jnp.ndarray, *, block_m: int = 128, block_k: int = 128
) -> jnp.ndarray:
    """Tiled Pallas matmul ``A @ B`` for A (m, k), B (k, n) with small n.

    Zero-pads every dimension up to the tile grid, computes in fp32, and
    slices back to the exact (m, n) result.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    bm = min(block_m, _ceil_to(m, 8))
    bk = min(block_k, _ceil_to(k, 8))
    mp, kp = _ceil_to(m, bm), _ceil_to(k, bk)
    npad = _ceil_to(n, 8)
    ap = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    bp = jnp.pad(b.astype(jnp.float32), ((0, kp - k), (0, npad - n)))

    grid = (mp // bm, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k_: (i, k_)),
            pl.BlockSpec((bk, npad), lambda i, k_: (k_, 0)),
        ],
        out_specs=pl.BlockSpec((bm, npad), lambda i, k_: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, npad), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]
