"""L1: Pallas kernels for the compute hot-spots of distributed eigenspace
estimation — tiled Gram accumulation, panel matmul, fused Newton–Schulz
polar / inverse-sqrt. Each has a pure-jnp oracle in ``ref``."""

from .gram import gram
from .matmul import matmul
from .polar import newton_schulz_polar, invsqrt_ns

__all__ = ["gram", "matmul", "newton_schulz_polar", "invsqrt_ns"]
