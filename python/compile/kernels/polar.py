"""L1 Pallas kernel: fused Newton–Schulz orthogonal polar factor.

The Procrustes alignment at the heart of Algorithm 1 needs the orthogonal
polar factor of the r x r cross-Gram ``A = V^T V_ref``; classically that is
``U W^T`` from an SVD, but SVD does not exist as a portable HLO op (it
lowers to a LAPACK custom-call the rust PJRT client cannot run, and Mosaic
on TPU). Instead we fuse the entire quadratically-convergent Newton–Schulz
iteration

    Y_0 = A / ||A||_F,     Y_{k+1} = 0.5 * Y_k (3 I - Y_k^T Y_k)

into ONE Pallas kernel invocation: the (r, r) iterate never leaves VMEM
(r <= 128 so the whole problem is a single MXU tile), and the T iterations
are a ``fori_loop`` inside the kernel body — zero HBM round-trips between
iterations. This mirrors how the paper's coordinator cost (Remark 1) is
dominated by m tiny r x r factorizations: on the accelerator they are
latency-, not bandwidth-, bound, so fusion is the entire game.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _polar_kernel(a_ref, o_ref, *, iters: int, r: int):
    a = a_ref[...]
    eye = jnp.eye(r, dtype=a.dtype)
    fro = jnp.sqrt(jnp.sum(a * a))
    y0 = a / jnp.maximum(fro, 1e-30)

    def body(_, y):
        return 0.5 * jnp.dot(y, 3.0 * eye - jnp.dot(y.T, y))

    o_ref[...] = jax.lax.fori_loop(0, iters, body, y0)


@functools.partial(jax.jit, static_argnames=("iters",))
def newton_schulz_polar(a: jnp.ndarray, *, iters: int = 18) -> jnp.ndarray:
    """Orthogonal polar factor of square ``a`` (r, r), fused in one kernel."""
    r = a.shape[0]
    assert a.shape == (r, r), "polar kernel expects a square matrix"
    return pl.pallas_call(
        functools.partial(_polar_kernel, iters=iters, r=r),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32))


def _invsqrt_kernel(g_ref, o_ref, *, iters: int, r: int):
    g = g_ref[...]
    eye = jnp.eye(r, dtype=g.dtype)
    a = jnp.maximum(jnp.trace(g), 1e-30)

    def body(_, yz):
        y, z = yz
        t = 0.5 * (3.0 * eye - jnp.dot(z, y))
        return jnp.dot(y, t), jnp.dot(t, z)

    _, z = jax.lax.fori_loop(0, iters, body, (g / a, eye))
    o_ref[...] = z / jnp.sqrt(a)


@functools.partial(jax.jit, static_argnames=("iters",))
def invsqrt_ns(g: jnp.ndarray, *, iters: int = 30) -> jnp.ndarray:
    """Fused coupled-Newton–Schulz ``G^{-1/2}`` for SPD ``g`` (r, r).

    Used by CholeskyQR (``Q = W (W^T W)^{-1/2}``) so that the L2 graph
    orthonormalizes panels without a QR custom-call.
    """
    r = g.shape[0]
    assert g.shape == (r, r), "invsqrt kernel expects a square matrix"
    return pl.pallas_call(
        functools.partial(_invsqrt_kernel, iters=iters, r=r),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        interpret=True,
    )(g.astype(jnp.float32))
