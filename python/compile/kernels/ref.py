"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package has a reference implementation here, written
with plain ``jax.numpy`` ops only. ``python/tests`` asserts kernel == ref
under ``numpy.testing.assert_allclose`` across shape/dtype sweeps
(hypothesis). The refs are also used by ``local_eigsolve_ref`` in
``python/tests/test_model.py`` to validate the full L2 graph against
``numpy.linalg.eigh``.

Nothing in this file may call ``jnp.linalg`` factorizations except the
*test-only* gold standard ``polar_svd_ref`` — the production L2 graph must
stay LAPACK-free (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Sample second-moment matrix ``(1/n) X^T X`` for ``X`` of shape (n, d)."""
    n = x.shape[0]
    return (x.T @ x) / n


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul reference."""
    return a @ b


def newton_schulz_polar_ref(a: jnp.ndarray, iters: int = 18) -> jnp.ndarray:
    """Orthogonal polar factor of a square matrix via Newton–Schulz.

    ``Y_{k+1} = 0.5 * Y_k (3 I - Y_k^T Y_k)`` converges quadratically to the
    polar factor ``U V^T`` (where ``A = U S V^T``) whenever all singular
    values of the initial iterate lie in ``(0, sqrt(3))``; we guarantee that
    by scaling with the Frobenius norm.
    """
    r = a.shape[0]
    eye = jnp.eye(r, dtype=a.dtype)
    y = a / jnp.maximum(jnp.sqrt(jnp.sum(a * a)), 1e-30)
    for _ in range(iters):
        y = 0.5 * y @ (3.0 * eye - y.T @ y)
    return y


def polar_svd_ref(a: jnp.ndarray) -> jnp.ndarray:
    """Exact polar factor via SVD (test-only gold standard)."""
    u, _, vt = jnp.linalg.svd(a, full_matrices=False)
    return u @ vt


def invsqrt_ns_ref(g: jnp.ndarray, iters: int = 30) -> jnp.ndarray:
    """Inverse matrix square root of an SPD matrix via coupled Newton–Schulz.

    Uses the coupled iteration ``T = (3I - Z Y)/2; Y <- Y T; Z <- T Z`` with
    ``Y0 = G/a, Z0 = I`` and scale ``a = trace(G)`` so that the spectrum of
    ``Y0`` lies in (0, 1]. On convergence ``Y -> I`` and ``Z -> (G/a)^{-1/2}``;
    returns ``G^{-1/2} = Z / sqrt(a)``.
    """
    r = g.shape[0]
    eye = jnp.eye(r, dtype=g.dtype)
    a = jnp.maximum(jnp.trace(g), 1e-30)
    y = g / a
    z = eye
    for _ in range(iters):
        t = 0.5 * (3.0 * eye - z @ y)
        y = y @ t
        z = t @ z
    return z / jnp.sqrt(a)


def cholqr_ref(w: jnp.ndarray, iters: int = 30) -> jnp.ndarray:
    """Orthonormalize the columns of ``w`` via CholeskyQR with NS inverse sqrt:
    ``Q = W (W^T W)^{-1/2}`` — LAPACK-free, matmul-dominant."""
    g = w.T @ w
    return w @ invsqrt_ns_ref(g, iters)


def orth_iter_ref(c: jnp.ndarray, v0: jnp.ndarray, steps: int) -> jnp.ndarray:
    """Block orthogonal iteration reference: repeat ``V <- cholqr(C V)``."""
    v = cholqr_ref(v0)
    for _ in range(steps):
        v = cholqr_ref(c @ v)
    return v


def local_eigsolve_ref(x: jnp.ndarray, v0: jnp.ndarray, steps: int):
    """Full local-solver reference: gram + orthogonal iteration + Ritz values."""
    c = gram_ref(x)
    v = orth_iter_ref(c, v0, steps)
    theta = jnp.diagonal(v.T @ (c @ v))
    return v, theta


def procrustes_align_ref(v: jnp.ndarray, v_ref: jnp.ndarray) -> jnp.ndarray:
    """Reference Procrustes alignment: ``V Z`` with
    ``Z = argmin_{Z in O_r} ||V Z - V_ref||_F = polar(V^T V_ref)``."""
    return v @ newton_schulz_polar_ref(v.T @ v_ref)
