"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Run once by ``make artifacts``; the rust runtime
(``rust/src/runtime/pjrt.rs``) loads the text with
``HloModuleProto::from_text_file``, compiles on the PJRT CPU client, and
executes — Python never runs at request time.

HLO TEXT (not ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate links)
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.

PJRT executables are fixed-shape, so we emit one artifact per (graph,
shape) pair listed in ``SHAPE_MANIFEST`` and a ``manifest.json`` the rust
side uses to pick the right executable. The wide statistical sweeps run on
the rust-native engine (same algorithm, any shape); examples and
integration tests exercise these PJRT artifacts end-to-end.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, builder, [input shapes]) — every entry becomes artifacts/<key>.hlo.txt
# local_eig:    x (n, d), v0 (d, r)   -> (V (d, r), theta (r,))
# local_eig_cov: c (d, d), v0 (d, r)  -> (V (d, r), theta (r,))
# procrustes:   v (d, r), vref (d, r) -> (V Z (d, r),)
# gram:         x (n, d)              -> (C (d, d),)
SHAPE_MANIFEST = [
    ("local_eig", "local_eig", [(500, 64), (64, 8)]),
    ("local_eig", "local_eig", [(200, 32), (32, 4)]),
    ("local_eig", "local_eig", [(1000, 128), (128, 16)]),
    ("local_eig_cov", "local_eig_cov", [(64, 64), (64, 8)]),
    ("local_eig_cov", "local_eig_cov", [(128, 128), (128, 16)]),
    ("procrustes", "procrustes", [(64, 8), (64, 8)]),
    ("procrustes", "procrustes", [(32, 4), (32, 4)]),
    ("procrustes", "procrustes", [(128, 16), (128, 16)]),
    ("gram", "gram", [(500, 64)]),
]


def _builders():
    return {
        "local_eig": lambda x, v0: model.local_eigsolve(x, v0),
        "local_eig_cov": lambda c, v0: model.local_eigsolve_cov(c, v0),
        "procrustes": lambda v, vref: (model.procrustes_align(v, vref),),
        "gram": lambda x: (model.gram_cov(x),),
    }


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps a single tuple result uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_key(name: str, shapes) -> str:
    dims = "_".join("x".join(str(d) for d in s) for s in shapes)
    return f"{name}__{dims}"


def build_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    builders = _builders()
    manifest = []
    for name, builder_name, shapes in SHAPE_MANIFEST:
        fn = builders[builder_name]
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        key = artifact_key(name, shapes)
        path = os.path.join(out_dir, f"{key}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            list(getattr(o, "shape", ())) for o in lowered.out_info
        ] if hasattr(lowered, "out_info") else []
        manifest.append(
            {
                "name": name,
                "key": key,
                "file": f"{key}.hlo.txt",
                "inputs": [list(s) for s in shapes],
                "outputs": out_shapes,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(manifest)} artifacts)")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact output dir")
    args = p.parse_args()
    build_all(args.out)


if __name__ == "__main__":
    main()
