"""L2: JAX compute graphs for the local node of distributed eigenspace
estimation, built on the L1 Pallas kernels.

Three graphs are AOT-lowered (``aot.py``) and executed from the rust
coordinator via PJRT — Python is never on the request path:

``local_eigsolve(x, v0)``
    The per-node solver: empirical second-moment ``C = (1/n) X^T X``
    (tiled Pallas Gram kernel), then ``STEPS`` rounds of block orthogonal
    iteration ``V <- cholqr(C V)`` (Pallas panel matmul + fused
    Newton–Schulz CholeskyQR), then Ritz values ``diag(V^T C V)``.
    ``v0`` is the random initial panel — the HOST supplies randomness, so
    the graph is a pure deterministic function (reproducibility lives in
    the rust PCG64 substrate).

``procrustes_align(v, v_ref)``
    Algorithm 1's inner step: ``V Z`` with
    ``Z = argmin_{Z in O_r} ||V Z - V_ref||_F = polar(V^T V_ref)``
    computed by the fused Newton–Schulz polar kernel.

``gram_cov(x)``
    Standalone covariance/second-moment formation (used by the streaming
    covariance example and the quadratic-sensing D_N assembly).

All factorizations are matmul-dominant iterations (no LAPACK/Mosaic
custom-calls) so the lowered HLO text compiles on any PJRT backend —
see DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import gram, matmul, newton_schulz_polar, invsqrt_ns

# Orthogonal-iteration steps baked into the AOT artifact. Convergence is
# linear with ratio (lambda_{r+1}/lambda_r); paper-style gaps (delta >= 0.1
# after normalization) need ~30 steps to drive the iteration error well
# below statistical noise. Validated against numpy.linalg.eigh in tests.
DEFAULT_STEPS = 30

# Newton–Schulz iteration counts (see kernels/polar.py for convergence).
POLAR_ITERS = 18
INVSQRT_ITERS = 30


def cholqr(w: jnp.ndarray) -> jnp.ndarray:
    """Orthonormalize the columns of a (d, r) panel: ``W (W^T W)^{-1/2}``.

    Matmul-only CholeskyQR; the r x r inverse square root runs in the fused
    Newton–Schulz Pallas kernel.
    """
    g = jnp.dot(w.T, w)
    return jnp.dot(w, invsqrt_ns(g, iters=INVSQRT_ITERS))


def orth_iter(c: jnp.ndarray, v0: jnp.ndarray, steps: int) -> jnp.ndarray:
    """Block orthogonal iteration for the leading r-dim eigenspace of SPD c."""
    v = cholqr(v0)
    for _ in range(steps):
        v = cholqr(matmul(c, v))
    return v


def local_eigsolve(x: jnp.ndarray, v0: jnp.ndarray, steps: int = DEFAULT_STEPS):
    """Per-node local solve: (V_hat (d, r), ritz values (r,)) from samples x (n, d)."""
    c = gram(x)
    v = orth_iter(c, v0, steps)
    theta = jnp.sum(v * matmul(c, v), axis=0)
    return v, theta


def local_eigsolve_cov(c: jnp.ndarray, v0: jnp.ndarray, steps: int = DEFAULT_STEPS):
    """Like :func:`local_eigsolve` but starting from an already-formed
    symmetric matrix ``c`` (d, d) — the generic "noisy observation X-hat^i"
    setting of the paper (node embeddings, quadratic sensing)."""
    v = orth_iter(c, v0, steps)
    theta = jnp.sum(v * matmul(c, v), axis=0)
    return v, theta


def procrustes_align(v: jnp.ndarray, v_ref: jnp.ndarray) -> jnp.ndarray:
    """Align ``v`` with ``v_ref``: returns ``v @ polar(v^T v_ref)``."""
    a = jnp.dot(v.T, v_ref)
    z = newton_schulz_polar(a, iters=POLAR_ITERS)
    return jnp.dot(v, z)


def gram_cov(x: jnp.ndarray) -> jnp.ndarray:
    """Standalone (1/n) X^T X via the tiled Pallas Gram kernel."""
    return gram(x)


def jit_local_eigsolve(steps: int = DEFAULT_STEPS):
    return jax.jit(lambda x, v0: local_eigsolve(x, v0, steps))


def jit_local_eigsolve_cov(steps: int = DEFAULT_STEPS):
    return jax.jit(lambda c, v0: local_eigsolve_cov(c, v0, steps))


def jit_procrustes_align():
    return jax.jit(procrustes_align)


def jit_gram_cov():
    return jax.jit(gram_cov)
