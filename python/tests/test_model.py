"""L2 correctness: the AOT-lowered compute graphs against numpy/LAPACK.

The production graphs are LAPACK-free by construction; here (test-only) we
are allowed numpy.linalg as the gold standard.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SET = dict(deadline=None, max_examples=10)


def _gapped_cov(d, r, gap, seed, lo=0.7, hi=1.0):
    g = np.random.default_rng(seed)
    u = np.linalg.qr(g.standard_normal((d, d)))[0]
    evs = np.concatenate(
        [np.linspace(hi, lo, r), (lo - gap) * 0.9 ** np.arange(d - r)]
    )
    return ((u * evs) @ u.T).astype(np.float32), u[:, :r]


def _subspace_dist(a, b):
    return np.linalg.norm(a @ a.T - b @ b.T, 2)


# ---------------------------------------------------------------- cholqr


@settings(**SET)
@given(
    d=st.integers(min_value=4, max_value=100),
    r=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_cholqr_orthonormal_and_span(d, r, seed):
    r = min(r, d)
    w = np.random.default_rng(seed).standard_normal((d, r)).astype(np.float32)
    q = np.asarray(model.cholqr(w)).astype(np.float64)
    np.testing.assert_allclose(q.T @ q, np.eye(r), atol=2e-3)
    # same column span: projector of q equals projector of orth(w)
    qw = np.linalg.qr(w.astype(np.float64))[0]
    assert _subspace_dist(q, qw) < 5e-3


# -------------------------------------------------------------- orth_iter


def test_orth_iter_converges_to_leading_subspace():
    c, v1 = _gapped_cov(64, 8, 0.2, 0)
    v0 = np.random.default_rng(1).standard_normal((64, 8)).astype(np.float32)
    v = np.asarray(model.orth_iter(c, v0, 30))
    assert _subspace_dist(v.astype(np.float64), v1) < 1e-3


def test_orth_iter_rank_one():
    c, v1 = _gapped_cov(32, 1, 0.3, 5)
    v0 = np.random.default_rng(2).standard_normal((32, 1)).astype(np.float32)
    v = np.asarray(model.orth_iter(c, v0, 30))
    assert _subspace_dist(v.astype(np.float64), v1) < 1e-3


def test_orth_iter_matches_ref():
    c, _ = _gapped_cov(40, 4, 0.2, 9)
    v0 = np.random.default_rng(3).standard_normal((40, 4)).astype(np.float32)
    got = np.asarray(model.orth_iter(c, v0, 10))
    want = np.asarray(ref.orth_iter_ref(c, v0, 10))
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------- local_eigsolve


def test_local_eigsolve_matches_eigh():
    g = np.random.default_rng(11)
    d, r, n = 64, 8, 500
    c, _ = _gapped_cov(d, r, 0.2, 7)
    x = (g.standard_normal((n, d)) @ np.linalg.cholesky(
        c.astype(np.float64) + 1e-9 * np.eye(d)).T).astype(np.float32)
    v0 = g.standard_normal((d, r)).astype(np.float32)
    v, theta = model.jit_local_eigsolve()(x, v0)
    v = np.asarray(v).astype(np.float64)
    emp = x.astype(np.float64).T @ x.astype(np.float64) / n
    w, q = np.linalg.eigh(emp)
    assert _subspace_dist(v, q[:, -r:]) < 2e-3
    # Ritz values bracket the true eigenvalue range
    assert np.all(np.asarray(theta) > w[-r] - 0.05)
    assert np.all(np.asarray(theta) < w[-1] + 0.05)


def test_local_eigsolve_cov_matches_eigh():
    c, v1 = _gapped_cov(64, 8, 0.2, 13)
    v0 = np.random.default_rng(4).standard_normal((64, 8)).astype(np.float32)
    v, _ = model.jit_local_eigsolve_cov()(c, v0)
    assert _subspace_dist(np.asarray(v).astype(np.float64), v1) < 1e-3


# ------------------------------------------------------- procrustes_align


@settings(**SET)
@given(
    d=st.integers(min_value=6, max_value=80),
    r=st.integers(min_value=1, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_procrustes_align_optimal(d, r, seed):
    """Aligned distance must match the SVD-Procrustes optimum."""
    r = min(r, d // 2)
    g = np.random.default_rng(seed)
    vref = np.linalg.qr(g.standard_normal((d, r)))[0].astype(np.float32)
    # v = vref rotated by a random orthogonal + small noise, re-orthonormalized
    z = np.linalg.qr(g.standard_normal((r, r)))[0]
    v = np.linalg.qr(vref @ z + 0.05 * g.standard_normal((d, r)))[0].astype(np.float32)
    aligned = np.asarray(model.jit_procrustes_align()(v, vref)).astype(np.float64)
    # optimum via SVD
    u, _, vt = np.linalg.svd(v.astype(np.float64).T @ vref.astype(np.float64))
    opt = v.astype(np.float64) @ (u @ vt)
    assert np.linalg.norm(aligned - vref, "fro") <= np.linalg.norm(opt - vref, "fro") + 1e-3


def test_procrustes_align_rotation_invariance():
    """align(V Q, ref) spans == align(V, ref) spans, and both ≈ ref-aligned."""
    g = np.random.default_rng(21)
    d, r = 40, 6
    vref = np.linalg.qr(g.standard_normal((d, r)))[0].astype(np.float32)
    v = np.linalg.qr(vref + 0.1 * g.standard_normal((d, r)))[0].astype(np.float32)
    q = np.linalg.qr(g.standard_normal((r, r)))[0].astype(np.float32)
    a1 = np.asarray(model.jit_procrustes_align()(v, vref))
    a2 = np.asarray(model.jit_procrustes_align()((v @ q).astype(np.float32), vref))
    np.testing.assert_allclose(a1, a2, atol=5e-3)


def test_procrustes_align_sign_fix_r1():
    """r=1 must reduce exactly to the sign-fixing scheme of Garber et al."""
    g = np.random.default_rng(31)
    d = 50
    vref = g.standard_normal((d, 1))
    vref /= np.linalg.norm(vref)
    v = -(vref + 0.05 * g.standard_normal((d, 1)))
    v /= np.linalg.norm(v)
    aligned = np.asarray(
        model.jit_procrustes_align()(v.astype(np.float32), vref.astype(np.float32))
    )
    s = np.sign(float((v.T @ vref)[0, 0]))
    np.testing.assert_allclose(aligned, s * v, atol=1e-4)


def test_procrustes_idempotent():
    g = np.random.default_rng(41)
    d, r = 30, 4
    vref = np.linalg.qr(g.standard_normal((d, r)))[0].astype(np.float32)
    v = np.linalg.qr(vref + 0.1 * g.standard_normal((d, r)))[0].astype(np.float32)
    once = np.asarray(model.jit_procrustes_align()(v, vref))
    twice = np.asarray(model.jit_procrustes_align()(once, vref))
    np.testing.assert_allclose(once, twice, atol=1e-3)
