"""Widened L1/L2 coverage: dtype handling, tile-boundary edge shapes,
iteration-count sensitivity, and cross-kernel composition properties that
the basic suites don't touch.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gram, invsqrt_ns, matmul, newton_schulz_polar
from compile.kernels import ref

SET = dict(deadline=None, max_examples=15)


def _rng(seed):
    return np.random.default_rng(seed)


# ------------------------------------------------------------- dtypes


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_gram_accepts_both_float_dtypes(dtype):
    x = _rng(0).standard_normal((64, 16)).astype(dtype)
    out = np.asarray(gram(x))
    assert out.dtype == np.float32  # kernels compute in f32
    np.testing.assert_allclose(out, ref.gram_ref(x.astype(np.float32)), rtol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_matmul_accepts_both_float_dtypes(dtype):
    g = _rng(1)
    a = g.standard_normal((20, 12)).astype(dtype)
    b = g.standard_normal((12, 4)).astype(dtype)
    out = np.asarray(matmul(a, b))
    assert out.dtype == np.float32
    np.testing.assert_allclose(
        out, (a.astype(np.float64) @ b.astype(np.float64)), rtol=1e-4, atol=1e-4
    )


# --------------------------------------------- exact tile boundaries


@pytest.mark.parametrize("n", [127, 128, 129, 256])
@pytest.mark.parametrize("d", [7, 8, 128])
def test_gram_tile_boundaries(n, d):
    x = _rng(n * d).standard_normal((n, d)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(gram(x)), ref.gram_ref(x), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("m,k", [(128, 128), (129, 127), (1, 128), (128, 1)])
def test_matmul_tile_boundaries(m, k):
    g = _rng(m * 1000 + k)
    a = g.standard_normal((m, k)).astype(np.float32)
    b = g.standard_normal((k, 3)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(matmul(a, b)), a @ b, rtol=1e-4, atol=1e-4
    )


# ------------------------------------------ iteration-count behaviour


def test_polar_iteration_monotone_convergence():
    """More NS iterations never worsen orthogonality defect."""
    a = _rng(5).standard_normal((10, 10)).astype(np.float32) * 0.3 + np.eye(
        10, dtype=np.float32
    )
    defects = []
    for iters in (4, 8, 16, 32):
        z = np.asarray(newton_schulz_polar(a, iters=iters)).astype(np.float64)
        defects.append(np.abs(z.T @ z - np.eye(10)).max())
    assert defects[-1] <= defects[0]
    assert defects[-1] < 1e-5


def test_invsqrt_iteration_monotone_convergence():
    g = _rng(6)
    q = np.linalg.qr(g.standard_normal((8, 8)))[0]
    spd = ((q * np.linspace(1.5, 0.4, 8)) @ q.T).astype(np.float32)
    errs = []
    for iters in (10, 20, 40):
        z = np.asarray(invsqrt_ns(spd, iters=iters)).astype(np.float64)
        errs.append(np.abs(z @ spd @ z - np.eye(8)).max())
    assert errs[-1] <= errs[0] + 1e-6  # equal up to f32 roundoff once converged
    assert errs[-1] < 1e-4


@settings(**SET)
@given(steps=st.integers(min_value=5, max_value=40))
def test_orth_iter_more_steps_never_hurts(steps):
    g = np.random.default_rng(7)
    d, r = 32, 3
    q = np.linalg.qr(g.standard_normal((d, d)))[0]
    evs = np.concatenate([[1.0, 0.95, 0.9], 0.5 * 0.8 ** np.arange(d - r)])
    c = ((q * evs) @ q.T).astype(np.float32)
    v0 = g.standard_normal((d, r)).astype(np.float32)
    v = np.asarray(model.orth_iter(c, v0, steps)).astype(np.float64)
    v_ref = q[:, :r]
    dist = np.linalg.norm(v @ v.T - v_ref @ v_ref.T, 2)
    # convergence ratio 0.5/0.9 per step from a random start
    assert dist < max(2.0 * (0.5 / 0.9) ** steps, 5e-3), f"steps={steps} dist={dist}"


# ------------------------------------------------- composition props


@settings(**SET)
@given(
    d=st.integers(min_value=8, max_value=64),
    r=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_align_then_average_beats_naive(d, r, seed):
    """The paper's core claim at kernel level: Procrustes-aligned averaging
    of rotated noisy copies tracks the truth; naive averaging does not."""
    r = min(r, d // 2)
    g = np.random.default_rng(seed)
    truth = np.linalg.qr(g.standard_normal((d, r)))[0].astype(np.float32)
    m = 8
    locals_, naive_sum = [], np.zeros((d, r))
    for _ in range(m):
        z = np.linalg.qr(g.standard_normal((r, r)))[0]
        v = np.linalg.qr(truth @ z + 0.05 * g.standard_normal((d, r)))[0].astype(
            np.float32
        )
        locals_.append(v)
        naive_sum += v
    align = model.jit_procrustes_align()
    acc = np.zeros((d, r))
    for v in locals_:
        acc += np.asarray(align(v, locals_[0]))
    avg = np.linalg.qr(acc / m)[0]
    naive = np.linalg.qr(naive_sum / m)[0]

    def dist(a):
        return np.linalg.norm(
            a @ a.T - truth.astype(np.float64) @ truth.astype(np.float64).T, 2
        )

    assert dist(avg) <= dist(naive) + 1e-6


def test_local_eigsolve_insensitive_to_init():
    """Different random inits must reach the same subspace (gap present)."""
    g = np.random.default_rng(8)
    d, r, n = 48, 4, 800
    q = np.linalg.qr(g.standard_normal((d, d)))[0]
    evs = np.concatenate([np.linspace(1.0, 0.8, r), 0.4 * 0.9 ** np.arange(d - r)])
    L = (q * np.sqrt(evs)).astype(np.float64)
    x = (g.standard_normal((n, d)) @ L.T).astype(np.float32)
    solve = model.jit_local_eigsolve()
    v1 = np.asarray(solve(x, g.standard_normal((d, r)).astype(np.float32))[0])
    v2 = np.asarray(solve(x, g.standard_normal((d, r)).astype(np.float32))[0])
    dist = np.linalg.norm(
        v1.astype(np.float64) @ v1.T - v2.astype(np.float64) @ v2.T, 2
    )
    assert dist < 1e-3, f"init sensitivity {dist}"


def test_gram_then_eigsolve_equals_direct_eigsolve():
    """local_eigsolve(x) == local_eigsolve_cov(gram(x)) — the two AOT
    entry points must agree."""
    g = np.random.default_rng(9)
    d, r, n = 32, 4, 300
    x = g.standard_normal((n, d)).astype(np.float32)
    v0 = g.standard_normal((d, r)).astype(np.float32)
    v_a, t_a = model.jit_local_eigsolve()(x, v0)
    c = np.asarray(model.jit_gram_cov()(x))
    v_b, t_b = model.jit_local_eigsolve_cov()(c, v0)
    np.testing.assert_allclose(np.asarray(v_a), np.asarray(v_b), atol=5e-4)
    np.testing.assert_allclose(np.asarray(t_a), np.asarray(t_b), atol=5e-4)
