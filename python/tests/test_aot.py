"""AOT pipeline checks: artifacts on disk are consistent with the manifest
and with a freshly lowered graph; HLO text is parseable interchange."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_files_exist():
    for entry in _manifest():
        assert os.path.exists(os.path.join(ART, entry["file"])), entry["key"]


def test_manifest_covers_shape_manifest():
    keys = {e["key"] for e in _manifest()}
    for name, _, shapes in aot.SHAPE_MANIFEST:
        assert aot.artifact_key(name, shapes) in keys


def test_hlo_text_has_entry_computation():
    for entry in _manifest():
        with open(os.path.join(ART, entry["file"])) as f:
            text = f.read()
        assert "ENTRY" in text, entry["key"]
        assert "HloModule" in text, entry["key"]


def test_hlo_text_is_lapack_free():
    """No custom-calls to LAPACK — the portability invariant that lets the
    rust PJRT CPU client compile the artifact (DESIGN.md §Hardware-Adaptation)."""
    for entry in _manifest():
        with open(os.path.join(ART, entry["file"])) as f:
            text = f.read()
        assert "lapack" not in text.lower(), entry["key"]


def test_lowering_deterministic():
    spec = jax.ShapeDtypeStruct((32, 4), jnp.float32)
    low1 = jax.jit(lambda v, w: (model.procrustes_align(v, w),)).lower(spec, spec)
    low2 = jax.jit(lambda v, w: (model.procrustes_align(v, w),)).lower(spec, spec)
    assert aot.to_hlo_text(low1) == aot.to_hlo_text(low2)


def test_artifact_key_format():
    assert aot.artifact_key("gram", [(500, 64)]) == "gram__500x64"
    assert (
        aot.artifact_key("local_eig", [(500, 64), (64, 8)])
        == "local_eig__500x64_64x8"
    )


def test_manifest_shapes_match_outputs():
    for entry in _manifest():
        if entry["name"] in ("local_eig", "local_eig_cov"):
            (d, r) = entry["inputs"][1]
            assert entry["outputs"][0] == [d, r]
            assert entry["outputs"][1] == [r]
        elif entry["name"] == "procrustes":
            assert entry["outputs"][0] == entry["inputs"][0]
        elif entry["name"] == "gram":
            n, d = entry["inputs"][0]
            assert entry["outputs"][0] == [d, d]
