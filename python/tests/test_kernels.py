"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (and, where relevant, conditioning) so we
exercise the padding/tiling edge cases of the BlockSpec schedules, not
just the happy 128-aligned path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, matmul, newton_schulz_polar, invsqrt_ns
from compile.kernels import ref

SET = dict(deadline=None, max_examples=25)


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- gram


@settings(**SET)
@given(
    n=st.integers(min_value=1, max_value=300),
    d=st.integers(min_value=1, max_value=80),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gram_matches_ref(n, d, seed):
    x = _rng(seed).standard_normal((n, d)).astype(np.float32)
    got = np.asarray(gram(x))
    want = np.asarray(ref.gram_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SET)
@given(
    n=st.integers(min_value=2, max_value=200),
    d=st.integers(min_value=2, max_value=64),
    bn=st.sampled_from([8, 32, 128]),
    bd=st.sampled_from([8, 32, 128]),
)
def test_gram_tile_invariance(n, d, bn, bd):
    """The result must not depend on the tiling schedule."""
    x = _rng(n * 1000 + d).standard_normal((n, d)).astype(np.float32)
    a = np.asarray(gram(x, block_n=bn, block_d=bd))
    b = np.asarray(gram(x, block_n=128, block_d=128))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_gram_symmetry_and_psd():
    x = _rng(7).standard_normal((123, 45)).astype(np.float32)
    c = np.asarray(gram(x))
    np.testing.assert_allclose(c, c.T, atol=1e-6)
    w = np.linalg.eigvalsh(c.astype(np.float64))
    assert w.min() > -1e-5


def test_gram_zero_input():
    c = np.asarray(gram(np.zeros((10, 6), np.float32)))
    np.testing.assert_allclose(c, 0.0)


def test_gram_single_sample():
    x = _rng(3).standard_normal((1, 17)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(gram(x)), np.outer(x[0], x[0]), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------- matmul


@settings(**SET)
@given(
    m=st.integers(min_value=1, max_value=180),
    k=st.integers(min_value=1, max_value=180),
    n=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    g = _rng(seed)
    a = g.standard_normal((m, k)).astype(np.float32)
    b = g.standard_normal((k, n)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(matmul(a, b)), a @ b, rtol=1e-4, atol=1e-4
    )


def test_matmul_identity():
    a = _rng(5).standard_normal((64, 64)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(matmul(a, np.eye(64, dtype=np.float32))), a, rtol=1e-6
    )


def test_matmul_tile_invariance():
    g = _rng(11)
    a = g.standard_normal((200, 150)).astype(np.float32)
    b = g.standard_normal((150, 12)).astype(np.float32)
    x = np.asarray(matmul(a, b, block_m=32, block_k=64))
    y = np.asarray(matmul(a, b, block_m=128, block_k=128))
    np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)


def test_matmul_shape_mismatch_raises():
    a = np.zeros((4, 5), np.float32)
    b = np.zeros((6, 2), np.float32)
    with pytest.raises(AssertionError):
        matmul(a, b)


# ---------------------------------------------------------------- polar


def _near_orthogonal(r, noise, seed):
    g = _rng(seed)
    q = np.linalg.qr(g.standard_normal((r, r)))[0]
    return (q + noise * g.standard_normal((r, r))).astype(np.float32)


@settings(**SET)
@given(
    r=st.integers(min_value=1, max_value=24),
    noise=st.floats(min_value=0.0, max_value=0.2),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_polar_matches_svd(r, noise, seed):
    a = _near_orthogonal(r, noise, seed)
    got = np.asarray(newton_schulz_polar(a, iters=30))
    want = np.asarray(ref.polar_svd_ref(a))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(**SET)
@given(
    r=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_polar_output_orthogonal(r, seed):
    a = _near_orthogonal(r, 0.3, seed)
    z = np.asarray(newton_schulz_polar(a, iters=40)).astype(np.float64)
    np.testing.assert_allclose(z.T @ z, np.eye(r), atol=5e-4)


def test_polar_of_orthogonal_is_identity_map():
    q = np.linalg.qr(_rng(2).standard_normal((12, 12)))[0].astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(newton_schulz_polar(q, iters=20)), q, atol=1e-4
    )


def test_polar_matches_jnp_ref_kernel_vs_ref():
    a = _near_orthogonal(8, 0.1, 99)
    got = np.asarray(newton_schulz_polar(a, iters=18))
    want = np.asarray(ref.newton_schulz_polar_ref(a, iters=18))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_polar_sign_fix_scalar():
    """r=1 polar is exactly the sign — the Garber et al. reduction."""
    for v in (0.7, -0.3, 2.5, -1e-3):
        z = float(np.asarray(newton_schulz_polar(np.array([[v]], np.float32), iters=40))[0, 0])
        assert abs(z - np.sign(v)) < 1e-4


# ---------------------------------------------------------------- invsqrt


@settings(**SET)
@given(
    r=st.integers(min_value=1, max_value=20),
    cond=st.floats(min_value=1.0, max_value=50.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_invsqrt_inverts(r, cond, seed):
    g = _rng(seed)
    q = np.linalg.qr(g.standard_normal((r, r)))[0]
    evs = np.linspace(1.0, 1.0 / cond, r)
    spd = ((q * evs) @ q.T).astype(np.float32)
    z = np.asarray(invsqrt_ns(spd, iters=60)).astype(np.float64)
    np.testing.assert_allclose(z @ spd.astype(np.float64) @ z, np.eye(r), atol=5e-3)


def test_invsqrt_matches_ref():
    g = _rng(4)
    q = np.linalg.qr(g.standard_normal((10, 10)))[0]
    spd = ((q * np.linspace(2.0, 0.5, 10)) @ q.T).astype(np.float32)
    got = np.asarray(invsqrt_ns(spd, iters=30))
    want = np.asarray(ref.invsqrt_ns_ref(spd, iters=30))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_invsqrt_identity():
    z = np.asarray(invsqrt_ns(np.eye(6, dtype=np.float32), iters=30))
    np.testing.assert_allclose(z, np.eye(6), atol=1e-5)
