//! # Distributed node embeddings (paper §3.6)
//!
//! Each of `m` machines observes a *censored* copy of a graph (every edge
//! independently hidden with probability `p = 0.1`), computes HOPE/Katz
//! node embeddings locally, and the coordinator combines them. Because the
//! implicit-factorization loss `||Z Z^T - S||_F^2` is invariant to
//! `Z -> Z Q`, the local embeddings are arbitrarily rotated relative to
//! each other — exactly the ambiguity Procrustes fixing resolves.
//!
//! We reproduce the paper's qualitative findings:
//! - the aligned average stays close to the "central" embedding (computed
//!   on the uncensored graph) as `m` grows, while naive averaging drifts
//!   (Fig 9);
//! - a downstream node classifier on the aligned embedding loses (almost)
//!   no macro-F1 vs the central embedding (Table 2).
//!
//! The Wikipedia/PPI datasets are not available offline; we use a
//! stochastic block model with planted community labels (DESIGN.md
//! substitution ledger).
//!
//! Run: `cargo run --release --example node_embeddings`

use deigen::align;
use deigen::classify::macro_f1_experiment;
use deigen::graph::{hope_embedding, sbm};
use deigen::linalg::procrustes::procrustes_align;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;

/// Embedding-space distance used by Fig 9: relative Frobenius distance of
/// the aligned estimate from the central embedding (aligning first, since
/// even the central embedding is only defined up to rotation).
fn rel_dist(z: &Mat, z_central: &Mat) -> f64 {
    let aligned = procrustes_align(z, z_central);
    aligned.sub(z_central).fro_norm() / z_central.fro_norm()
}

fn main() {
    let seed = 20200504u64;
    let mut rng = Pcg64::seed(seed);
    let (nodes, communities) = (220usize, 4usize);
    let dim = 32usize;
    let beta = 0.02;
    let p_hide = 0.1;

    println!("deigen node embeddings: SBM n={nodes} k={communities}, HOPE dim={dim}, censor p={p_hide}");
    let g = sbm(nodes, communities, 0.25, 0.02, &mut rng);
    println!("graph: {} edges", g.m());

    // central embedding on the uncensored graph
    let z_central = hope_embedding(&g, dim, beta);
    let f1_central = macro_f1_experiment(&z_central, &g.labels, communities, 1.0, &mut rng);
    println!(
        "central embedding: macro-F1 {:.3}, accuracy {:.3}",
        f1_central.macro_f1, f1_central.accuracy
    );

    println!("\n  m    dist(aligned)  dist(naive)   rel F1 change");
    println!("  ---  -------------  -----------   -------------");
    for &m in &[4usize, 8, 16, 32] {
        // per-machine censored views + local embeddings
        let locals: Vec<Mat> = (0..m)
            .map(|_| {
                let cg = g.censor(p_hide, &mut rng);
                hope_embedding(&cg, dim, beta)
            })
            .collect();

        // Procrustes-aligned average (Algorithm 1 on non-orthonormal panels:
        // alignment minimizes ||Z_i Q - Z_1||_F over orthogonal Q)
        let mut acc = Mat::zeros(nodes, dim);
        for z in &locals {
            acc.axpy(1.0 / m as f64, &procrustes_align(z, &locals[0]));
        }
        let z_avg = acc;
        // naive average
        let mut z_naive = Mat::zeros(nodes, dim);
        for z in &locals {
            z_naive.axpy(1.0 / m as f64, z);
        }

        let da = rel_dist(&z_avg, &z_central);
        let dn = rel_dist(&z_naive, &z_central);
        let f1 = macro_f1_experiment(&z_avg, &g.labels, communities, 1.0, &mut rng);
        let rel_f1 = (f1_central.macro_f1 - f1.macro_f1) / f1_central.macro_f1;
        println!(
            "  {m:>3}  {da:>13.4}  {dn:>11.4}   {:>+12.2}%",
            100.0 * rel_f1
        );
    }

    // Fig-9 shape check at the largest m
    let locals: Vec<Mat> = (0..32)
        .map(|_| hope_embedding(&g.censor(p_hide, &mut rng), dim, beta))
        .collect();
    let mut acc = Mat::zeros(nodes, dim);
    for z in &locals {
        acc.axpy(1.0 / 32.0, &procrustes_align(z, &locals[0]));
    }
    let mut z_naive = Mat::zeros(nodes, dim);
    for z in &locals {
        z_naive.axpy(1.0 / 32.0, z);
    }
    let da = rel_dist(&acc, &z_central);
    let dn = rel_dist(&z_naive, &z_central);
    assert!(
        da < dn,
        "aligned ({da:.3}) should stay closer to central than naive ({dn:.3})"
    );
    println!("\nnode_embeddings OK: aligned stays near the central embedding; naive drifts.");

    // make the unused import of align explicit-useful: sanity vs library fn
    let _ = align::naive_average(&[Pcg64::seed(1).haar_stiefel(8, 2)]);
}
