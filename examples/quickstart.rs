//! # Quickstart: end-to-end distributed PCA over the full three-layer stack
//!
//! This is the composition proof for the whole system:
//!
//! 1. `m = 10` simulated machines each draw `n = 500` Gaussian samples in
//!    `d = 64` dimensions from a shared population covariance with an
//!    `r = 8`-dimensional principal subspace (model M1 of the paper).
//! 2. Every machine runs the **AOT-compiled JAX/Pallas local solver**
//!    (`local_eig` artifact: tiled Pallas Gram kernel + orthogonal
//!    iteration + Newton–Schulz CholeskyQR) through the PJRT CPU client —
//!    no Python anywhere at runtime.
//! 3. The rust coordinator collects the `(d, r)` panels (ONE round of
//!    communication), Procrustes-aligns them against the first panel with
//!    the **AOT-compiled Newton–Schulz polar kernel**, averages, and QRs.
//! 4. We report subspace distances against the ground truth and against
//!    the centralized estimator, plus communication accounting — the
//!    paper's headline comparison (aligned ≈ central ≪ naive).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use deigen::align;
use deigen::coordinator::{CommStats, NetworkModel};
use deigen::linalg::subspace::dist2;
use deigen::linalg::Mat;
use deigen::rng::Pcg64;
use deigen::runtime::PjrtEngine;
use deigen::synth::{CovModel, SpectrumModel};

fn main() -> anyhow::Result<()> {
    let (m, n, d, r) = (10usize, 500usize, 64usize, 8usize);
    let seed = 20200504u64;
    println!("deigen quickstart: distributed PCA, m={m} n={n} d={d} r={r}");

    // --- population + per-machine samples --------------------------------
    let mut rng = Pcg64::seed(seed);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    let truth = cov.principal_subspace();
    println!(
        "population: eigengap={:.3} intdim={:.1}",
        cov.gap(),
        cov.intdim()
    );

    // --- PJRT engine: load + compile AOT artifacts -----------------------
    let mut engine = PjrtEngine::load_default()?;
    println!("PJRT platform: {}", engine.platform());

    // --- local solves on every "machine" (the request path) --------------
    let stats = CommStats::new();
    let mut panels: Vec<Mat> = Vec::with_capacity(m);
    let mut local_cov_sum = Mat::zeros(d, d);
    let t0 = std::time::Instant::now();
    for i in 0..m {
        let mut node_rng = rng.split(i as u64);
        let x = cov.sample(n, &mut node_rng);
        let v0 = node_rng.normal_mat(d, r);
        // L1+L2 compute, AOT-compiled, executed via PJRT:
        let (v, _ritz) = engine.local_eig(&x, &v0)?;
        local_cov_sum.axpy(1.0 / m as f64, &CovModel::empirical_cov(&x));
        // one panel upload per machine — the paper's single round
        stats.record_up(32 + 4 * d * r);
        panels.push(v);
    }
    stats.bump_round();
    let solve_time = t0.elapsed();

    // --- leader-side Procrustes fixing (Algorithm 1) via PJRT ------------
    let t1 = std::time::Instant::now();
    let mut acc = Mat::zeros(d, r);
    for v in &panels {
        let aligned = engine.procrustes(v, &panels[0])?;
        acc.axpy(1.0 / m as f64, &aligned);
    }
    let aligned_est = deigen::linalg::qr::orthonormalize(&acc);
    let align_time = t1.elapsed();

    // --- baselines --------------------------------------------------------
    let naive = align::naive_average(&panels);
    let central = deigen::linalg::eig::top_eigvecs(&local_cov_sum, r).0;

    let d_aligned = dist2(&aligned_est, &truth);
    let d_naive = dist2(&naive, &truth);
    let d_central = dist2(&central, &truth);

    println!("\n  estimator      dist2 to truth");
    println!("  -----------    --------------");
    println!("  central        {d_central:.4}");
    println!("  aligned (A1)   {d_aligned:.4}");
    println!("  naive avg      {d_naive:.4}");

    let snap = stats.snapshot();
    let net = NetworkModel::wan();
    println!(
        "\ncommunication: {} rounds, {} B up ({m} panels); simulated WAN time {:.3}s",
        snap.rounds,
        snap.bytes_up,
        stats.simulated_time(&net),
    );
    println!(
        "compute: {m} local PJRT solves in {solve_time:?}, alignment in {align_time:?}"
    );

    // --- the paper's claim, as assertions ---------------------------------
    assert!(
        d_aligned < 3.0 * d_central + 0.05,
        "aligned should track the centralized estimator"
    );
    assert!(
        d_naive > 2.0 * d_aligned,
        "naive averaging should be much worse (rotation ambiguity)"
    );
    println!("\nquickstart OK: aligned ≈ central ≪ naive — the paper's headline result.");
    Ok(())
}
