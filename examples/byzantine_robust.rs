//! # Byzantine-robust distributed eigenspace estimation (paper §4, future work)
//!
//! The paper closes by asking what happens when *some machines are
//! compromised* and upload arbitrary orthonormal panels instead of honest
//! local estimates. This example runs the full threaded coordinator with
//! injected Byzantine workers and compares:
//!
//! - plain Algorithm 1 (mean aggregation, default reference = node 0);
//! - the robust extension: median-distance reference selection +
//!   coordinate-wise median aggregation.
//!
//! Run: `cargo run --release --example byzantine_robust`

use std::sync::Arc;

use deigen::align;
use deigen::coordinator::{
    run_cluster, AggregationRule, ClusterConfig, NodeBehavior, Shard, WorkerData,
};
use deigen::linalg::subspace::dist2;
use deigen::rng::Pcg64;
use deigen::runtime::NativeEngine;
use deigen::synth::{CovModel, SpectrumModel};

fn make_workers(
    cov: &CovModel,
    n: usize,
    m: usize,
    byz: usize,
    rng: &mut Pcg64,
) -> Vec<WorkerData> {
    (0..m)
        .map(|i| {
            let x = cov.sample(n, &mut rng.split(i as u64));
            WorkerData {
                // workers hold raw sample shards; the Gram operator plane
                // solves without forming any d x d covariance
                shard: Shard::Samples(x),
                behavior: if i != 0 && i <= byz {
                    // compromise nodes 1..=byz (keep node 0 honest so the
                    // *default-reference* failure mode is probed separately)
                    NodeBehavior::Byzantine
                } else {
                    NodeBehavior::Honest
                },
            }
        })
        .collect()
}

fn main() {
    let seed = 20200504u64;
    let mut rng = Pcg64::seed(seed);
    let (d, r, m, n) = (48usize, 4usize, 20usize, 400usize);
    let model = SpectrumModel::M1 { r, lambda_lo: 0.5, lambda_hi: 1.0, delta: 0.2 };
    let cov = CovModel::draw(&model, d, &mut rng);
    let truth = cov.principal_subspace();

    println!("deigen byzantine: d={d} r={r} m={m} n={n}");
    println!("\n  #byz  dist(mean agg)  dist(median agg)");
    println!("  ----  --------------  ----------------");
    for byz in [0usize, 2, 4, 6] {
        let mk = |agg| {
            let workers = make_workers(&cov, n, m, byz, &mut Pcg64::seed(seed + byz as u64));
            let cfg = ClusterConfig {
                r,
                aggregation: agg,
                seed: seed + byz as u64,
                ..Default::default()
            };
            run_cluster(workers, Arc::new(NativeEngine::default()), &cfg)
        };
        let mean = mk(AggregationRule::Mean);
        let med = mk(AggregationRule::CoordinateMedian);
        let dm = dist2(&mean.estimate, &truth);
        let dd = dist2(&med.estimate, &truth);
        println!("  {byz:>4}  {dm:>14.4}  {dd:>16.4}");
        if byz >= 4 {
            assert!(
                dd < dm + 0.05,
                "median aggregation should not be worse under heavy attack"
            );
        }
    }

    // and the worst case: the DEFAULT REFERENCE node itself is compromised
    let mut workers = make_workers(&cov, n, m, 0, &mut Pcg64::seed(seed + 99));
    workers[0].behavior = NodeBehavior::Byzantine;
    let cfg = ClusterConfig {
        r,
        aggregation: AggregationRule::CoordinateMedian,
        seed: seed + 99,
        ..Default::default()
    };
    let res = run_cluster(workers, Arc::new(NativeEngine::default()), &cfg);
    let dd = dist2(&res.estimate, &truth);
    println!("\ncompromised reference node, median agg + robust reference: dist {dd:.4}");
    assert!(dd < 0.3, "robust pipeline should survive a compromised reference");

    // cross-check the robust reference picker never chooses a junk panel
    let idx = align::robust_reference_index(&res.local_panels);
    println!("robust reference picked node {idx} (node 0 is Byzantine)");
    assert_ne!(idx, 0);
    println!("\nbyzantine_robust OK: the §4 extension holds up under an honest majority.");
}
