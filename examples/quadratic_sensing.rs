//! # Distributed spectral initialization for quadratic sensing (paper §3.7)
//!
//! `m = 30` machines each observe `n = i * r * d` quadratic measurements
//! `y = ||X_sharp^T a||^2` of a shared ground-truth `X_sharp in O_{d,r}`.
//! Each machine forms its truncated spectral matrix `D_N` and extracts a
//! weak local estimate; the coordinator refines by Procrustes fixing with
//! iterative refinement (Algorithm 2, n_iter = 10) — reproducing Fig 10's
//! finding that the distributed initialization weakly recovers `X_sharp`
//! once `n >~ 2 r d` per machine, while naive averaging stays near-orthogonal
//! to the signal.
//!
//! Run: `cargo run --release --example quadratic_sensing`

use deigen::align;
use deigen::rng::Pcg64;
use deigen::linalg::Mat;
use deigen::sensing::{local_init, SensingInstance};

fn main() {
    let seed = 20200504u64;
    let mut rng = Pcg64::seed(seed);
    let (d, r, m) = (60usize, 3usize, 30usize);
    println!("deigen quadratic sensing: d={d} r={r} m={m}, n = i*r*d per machine");
    let inst = SensingInstance::draw(d, r, 0.0, &mut rng);

    println!("\n  i    n/machine  leak(aligned)  leak(naive)  leak(local)");
    println!("  ---  ---------  -------------  -----------  -----------");
    let mut last_aligned = f64::NAN;
    for i in [1usize, 2, 4, 6] {
        let n = i * r * d;
        let locals: Vec<Mat> = (0..m)
            .map(|j| {
                let mut node_rng = rng.split((i * 100 + j) as u64);
                let (a, y) = inst.measure(n, &mut node_rng);
                local_init(&a, &y, r)
            })
            .collect();

        let refined = align::iterative_refinement(&locals, 10);
        let naive = align::naive_average(&locals);
        let leak_refined = inst.leakage(&refined);
        let leak_naive = inst.leakage(&naive);
        let leak_local = inst.leakage(&locals[0]);
        println!(
            "  {i:>3}  {n:>9}  {leak_refined:>13.4}  {leak_naive:>11.4}  {leak_local:>11.4}"
        );
        last_aligned = leak_refined;
    }

    assert!(
        last_aligned < 0.7,
        "distributed init should weakly recover X_sharp at n = 6rd (leak {last_aligned:.3})"
    );
    println!("\nquadratic_sensing OK: Algorithm 2 turns weak local spectral \
              estimates into a usable initialization.");
}
